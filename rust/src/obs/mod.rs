//! Structured tracing + metrics: attribute every virtual second of a step.
//!
//! Per-step aggregates (`measured_step_s`, `rank_idle_s`) say *that* a
//! configuration is slow; this module says *where* — per rank, per bucket,
//! per schedule round, on both the wall clock and the vfabric virtual
//! clock. It is the instrument the chunked-streaming and fleet-scale
//! roadmap items are validated with.
//!
//! # Architecture
//!
//! - A process-wide [`Tracer`] (one per trainer/bench run) owns the trace
//!   level, the epoch, the merged span sink, and the [`MetricsRegistry`].
//! - Each rank thread calls [`Tracer::install`] once; instrumented code
//!   then uses the free functions ([`span`], [`port_span`], [`vclock`],
//!   [`count`], [`observe`]) which write to a **thread-local collector** —
//!   the hot path takes no locks and allocates only for labels. Buffers
//!   are merged into the sink at [`flush`] (end of step) or on guard drop.
//! - The trainer drains the sink per step ([`Tracer::drain`]), stamping
//!   the step id, and assembles a [`TraceReport`] with exporters: Chrome
//!   `trace_event` JSON (one process per rank, one thread per [`Lane`] —
//!   open `TRACE_<name>.json` in Perfetto), a terminal critical-path
//!   summary, and the `TRACE_<name>.json` artifact itself.
//! - At [`TraceLevel::Sampled`] (`--trace sampled`) every span is instead
//!   **folded** into the streaming [`fleet::FleetTelemetry`] aggregate at
//!   record time — per-rank time totals, per-class fixed-layout log-bucket
//!   histograms ([`health`]), byte counters — and only exemplar ranks'
//!   spans reach the sink. The trainer freezes one [`StepHealth`] per step
//!   ([`Tracer::end_health_step`]) and exports `HEALTH_<name>.json`; this
//!   is the mode that scales to fleetsim's 4k–10k-rank runs.
//!
//! # Overhead contract
//!
//! With tracing off (the default), every entry point reduces to one
//! thread-local byte read and a branch — no allocation, no clock read, no
//! atomics. `benches/codec_micro.rs` asserts this stays under 100 ns per
//! call. Label closures ([`SpanGuard::label_with`]) only run when the span
//! is live.

pub mod export;
pub mod fleet;
pub mod health;
pub mod registry;
pub mod span;

pub use export::{StepWindow, TraceReport};
pub use fleet::{FleetTelemetry, HealthReport, RankFlag, StepHealth};
pub use health::{FixedHistogram, TimeClass};
pub use registry::{Counter, Histogram, MetricsRegistry};
pub use span::{check_nesting, Lane, Span, SpanKind};

use crate::vfabric::Scenario;
use std::cell::{Cell, RefCell};
use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// How much to record, per `--trace off|step|sampled|full`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum TraceLevel {
    /// No-op: the hot path reduces to a thread-local read + branch.
    #[default]
    Off = 0,
    /// Step anatomy only: compute / exchange / barrier per rank.
    Step = 1,
    /// Everything: codec, wire, merge, rounds, port occupancy, waits.
    Full = 2,
    /// Everything *observed*, but streamed into the [`fleet`] aggregator
    /// at record time; full spans are retained only for exemplar ranks.
    /// This is the fleet-scale mode: memory stays O(exemplars), not
    /// O(ranks × spans).
    Sampled = 3,
}

impl TraceLevel {
    pub fn parse(s: &str) -> anyhow::Result<TraceLevel> {
        match s {
            "off" => Ok(TraceLevel::Off),
            "step" => Ok(TraceLevel::Step),
            "full" => Ok(TraceLevel::Full),
            "sampled" => Ok(TraceLevel::Sampled),
            other => {
                anyhow::bail!("unknown trace level '{other}' (expected off|step|sampled|full)")
            }
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            TraceLevel::Off => "off",
            TraceLevel::Step => "step",
            TraceLevel::Full => "full",
            TraceLevel::Sampled => "sampled",
        }
    }
}

/// Process-wide trace collector for one run.
pub struct Tracer {
    level: TraceLevel,
    ranks: usize,
    epoch: Instant,
    sink: Mutex<Vec<Span>>,
    registry: MetricsRegistry,
    /// The streaming aggregator, present only at [`TraceLevel::Sampled`].
    health: Mutex<Option<FleetTelemetry>>,
}

impl Tracer {
    pub fn new(level: TraceLevel, ranks: usize) -> Arc<Tracer> {
        let health = (level == TraceLevel::Sampled).then(|| FleetTelemetry::new(ranks));
        Arc::new(Tracer {
            level,
            ranks,
            epoch: Instant::now(),
            sink: Mutex::new(Vec::new()),
            registry: MetricsRegistry::new(),
            health: Mutex::new(health),
        })
    }

    pub fn level(&self) -> TraceLevel {
        self.level
    }

    pub fn ranks(&self) -> usize {
        self.ranks
    }

    pub fn registry(&self) -> &MetricsRegistry {
        &self.registry
    }

    /// Wall seconds since the tracer epoch.
    pub fn now(&self) -> f64 {
        self.epoch.elapsed().as_secs_f64()
    }

    /// Push one span straight into the sink (cold path — used by the
    /// trainer to synthesise spans it computes after the fact, e.g. the
    /// end-of-step barrier gap per rank). At [`TraceLevel::Sampled`] the
    /// span is folded into the aggregate and retained only for exemplar
    /// ranks, like every other record path.
    pub fn record(&self, s: Span) {
        if self.level == TraceLevel::Off {
            return;
        }
        if self.fold(&s) {
            self.sink.lock().unwrap().push(s);
        }
    }

    /// Fold a span into the streaming aggregate when sampling; returns
    /// whether the span should also be retained verbatim.
    #[inline]
    fn fold(&self, s: &Span) -> bool {
        if self.level != TraceLevel::Sampled {
            return true;
        }
        match self.health.lock().unwrap().as_mut() {
            Some(t) => t.fold(s),
            None => true,
        }
    }

    /// Freeze the streaming aggregate's current step (no-op unless the
    /// tracer runs at [`TraceLevel::Sampled`]). Call once per step, after
    /// all of the step's spans have been recorded/flushed; `virt` is the
    /// step's virtual-clock window and `scenario` the injected weather to
    /// cross-check detector flags against.
    pub fn end_health_step(
        &self,
        step: u32,
        measured_s: f64,
        virt: (f64, f64),
        scenario: Option<&Scenario>,
    ) {
        if let Some(t) = self.health.lock().unwrap().as_mut() {
            t.end_step(step, measured_s, virt, scenario);
        }
    }

    /// Take the streaming aggregator out of the tracer (end of run);
    /// `None` unless the tracer runs at [`TraceLevel::Sampled`].
    pub fn take_health(&self) -> Option<FleetTelemetry> {
        self.health.lock().unwrap().take()
    }

    fn record_all(&self, spans: &mut Vec<Span>) {
        if spans.is_empty() {
            return;
        }
        self.sink.lock().unwrap().append(spans);
    }

    /// Take everything flushed so far, stamp it with `step`, and return it
    /// ordered by (rank, lane, start time). Called once per step by the
    /// trainer, or once at the end of a bench run.
    pub fn drain(&self, step: u32) -> Vec<Span> {
        let mut spans = std::mem::take(&mut *self.sink.lock().unwrap());
        for s in &mut spans {
            s.step = step;
        }
        spans.sort_by(|a, b| {
            (a.rank, a.lane)
                .cmp(&(b.rank, b.lane))
                .then_with(|| sort_key(a).partial_cmp(&sort_key(b)).unwrap())
        });
        spans
    }

    /// Bind this thread to `rank`: spans recorded on this thread go to the
    /// rank's lanes. Returns a guard that flushes and restores the
    /// previous binding on drop (bindings nest — the coordinator installs
    /// per-worker around encode sections).
    pub fn install(self: &Arc<Self>, rank: usize) -> InstallGuard {
        let prev = if self.level == TraceLevel::Off {
            TLS.with(|t| t.borrow_mut().take())
        } else {
            let c = Collector {
                tracer: self.clone(),
                rank: rank as u32,
                depth: 0,
                vnow: f64::NAN,
                buf: Vec::with_capacity(64),
                counters: HashMap::new(),
                hists: HashMap::new(),
            };
            TLS.with(|t| t.borrow_mut().replace(c))
        };
        let prev_level = LEVEL.with(|l| l.replace(self.level as u8));
        InstallGuard { prev, prev_level }
    }
}

fn sort_key(s: &Span) -> f64 {
    if s.wall0.is_finite() { s.wall0 } else { s.virt0 }
}

struct Collector {
    tracer: Arc<Tracer>,
    rank: u32,
    depth: u16,
    /// Latest virtual-clock stamp seen on this thread (NaN before the
    /// fabric first publishes one).
    vnow: f64,
    buf: Vec<Span>,
    // per-thread handle caches so count()/observe() stay lock-free after
    // the first touch of each name
    counters: HashMap<&'static str, Counter>,
    hists: HashMap<&'static str, Histogram>,
}

impl Collector {
    fn now(&self) -> f64 {
        self.tracer.now()
    }

    /// Buffer one finished span. This is the single chokepoint of every
    /// thread-local record path: at [`TraceLevel::Sampled`] the span is
    /// folded into the fleet aggregate here and buffered only when its
    /// rank is an exemplar, so non-exemplar spans never materialise.
    #[inline]
    fn push(&mut self, s: Span) {
        if self.tracer.fold(&s) {
            self.buf.push(s);
        }
    }
}

thread_local! {
    // fast-path gate: 0 = off, 1 = step, 2 = full, 3 = sampled
    static LEVEL: Cell<u8> = const { Cell::new(0) };
    static TLS: RefCell<Option<Collector>> = const { RefCell::new(None) };
    // lane [`span`] opens on; helper threads (the pipeline encoder)
    // override it via `lane_scope` so library spans opened inside their
    // closures (codec Pack/Decode, merge) land on the helper's lane
    // instead of colliding with the main thread's cpu-lane nesting
    static DEFAULT_LANE: Cell<Lane> = const { Cell::new(Lane::Cpu) };
}

/// Restores the thread's previous default span lane on drop.
pub struct LaneGuard {
    prev: Lane,
}

impl Drop for LaneGuard {
    fn drop(&mut self) {
        DEFAULT_LANE.with(|l| l.set(self.prev));
    }
}

/// Redirect this thread's [`span`] calls to `lane` until the guard drops.
///
/// The nesting checker treats each (rank, lane) pair as one timeline, so
/// a thread running concurrently with the rank's main timeline must keep
/// *all* its spans — including ones opened deep inside shared library
/// code such as [`crate::collective::sparse::SegmentCodec`] — off the
/// cpu lane. Explicit [`span_on`] calls are unaffected.
pub fn lane_scope(lane: Lane) -> LaneGuard {
    LaneGuard { prev: DEFAULT_LANE.with(|l| l.replace(lane)) }
}

/// Restores the previous thread binding (and flushes) on drop.
pub struct InstallGuard {
    prev: Option<Collector>,
    prev_level: u8,
}

impl Drop for InstallGuard {
    fn drop(&mut self) {
        flush();
        TLS.with(|t| *t.borrow_mut() = self.prev.take());
        LEVEL.with(|l| l.set(self.prev_level));
    }
}

#[inline]
fn lvl() -> u8 {
    LEVEL.with(|l| l.get())
}

#[inline]
fn enabled(kind: SpanKind) -> bool {
    let l = lvl();
    // full and sampled observe every kind (sampled folds at record time);
    // step keeps only the step-anatomy kinds
    l >= 2 || (l == 1 && kind.step_level())
}

/// RAII span: opened by [`span`], recorded into the thread buffer on drop.
/// When tracing is off (or the kind is below the level) the guard is dead
/// and every method is a branch on a bool.
pub struct SpanGuard {
    live: bool,
    kind: SpanKind,
    lane: Lane,
    bytes: u64,
    label: Option<Box<str>>,
    wall0: f64,
    virt0: f64,
}

impl SpanGuard {
    /// True when the span is being recorded (use to skip expensive
    /// bookkeeping that only feeds the trace).
    pub fn live(&self) -> bool {
        self.live
    }

    /// Attach payload bytes.
    pub fn set_bytes(&mut self, n: u64) {
        if self.live {
            self.bytes = n;
        }
    }

    /// Attach a label; the closure only runs when the span is live.
    pub fn label_with<F: FnOnce() -> String>(&mut self, f: F) {
        if self.live {
            self.label = Some(f().into_boxed_str());
        }
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if !self.live {
            return;
        }
        TLS.with(|t| {
            let mut b = t.borrow_mut();
            if let Some(c) = b.as_mut() {
                c.depth = c.depth.saturating_sub(1);
                let s = Span {
                    kind: self.kind,
                    lane: self.lane,
                    rank: c.rank,
                    step: 0,
                    depth: c.depth,
                    bytes: self.bytes,
                    label: self.label.take(),
                    wall0: self.wall0,
                    wall1: c.now(),
                    virt0: self.virt0,
                    virt1: c.vnow,
                };
                c.push(s);
            }
        });
    }
}

/// Open a span on the current thread's default lane (the cpu lane unless
/// a [`lane_scope`] override is active). Stamped with the wall clock now
/// and the virtual clock as of the latest [`vclock`] update; closed (and
/// buffered) when the guard drops.
#[inline]
pub fn span(kind: SpanKind) -> SpanGuard {
    span_on(kind, DEFAULT_LANE.with(|l| l.get()))
}

/// Open a span on an explicit lane of the current rank. Used by code that
/// runs concurrently with the rank's main timeline by design (the
/// double-buffered pipeline encoder records on [`Lane::Encoder`] so its
/// spans never violate the cpu lane's nesting invariant).
#[inline]
pub fn span_on(kind: SpanKind, lane: Lane) -> SpanGuard {
    if !enabled(kind) {
        return SpanGuard {
            live: false,
            kind,
            lane,
            bytes: 0,
            label: None,
            wall0: f64::NAN,
            virt0: f64::NAN,
        };
    }
    TLS.with(|t| {
        let mut b = t.borrow_mut();
        let c = b.as_mut().expect("obs: trace level set without a collector");
        c.depth += 1;
        SpanGuard {
            live: true,
            kind,
            lane,
            bytes: 0,
            label: None,
            wall0: c.now(),
            virt0: c.vnow,
        }
    })
}

/// Record a span with an explicit **virtual** extent on a port lane. The
/// virtual fabric books port occupancy into the future (sends are
/// non-blocking), so there is no RAII window to measure; wall stamps
/// record when the booking happened (a point).
pub fn port_span(kind: SpanKind, lane: Lane, v0: f64, v1: f64, bytes: u64) {
    if !enabled(kind) {
        return;
    }
    TLS.with(|t| {
        let mut b = t.borrow_mut();
        if let Some(c) = b.as_mut() {
            let w = c.now();
            c.push(Span {
                kind,
                lane,
                rank: c.rank,
                step: 0,
                depth: 0,
                bytes,
                label: None,
                wall0: w,
                wall1: w,
                virt0: v0,
                virt1: v1,
            });
        }
    });
}

/// Record a span for an explicit `rank` with a pure **virtual** extent
/// and no wall stamps (`NaN` walls serialise as `null`). Used by the
/// single-threaded fleet runner, which multiplexes every rank onto one
/// collector thread: the thread-local collector's rank/wall/vnow state
/// would be meaningless for the simulated ranks, so the caller supplies
/// the rank and the virtual window directly. Spans recorded this way are
/// bit-deterministic (no wall clock), which is what the fleetsim
/// determinism suite asserts on.
pub fn virtual_span(kind: SpanKind, lane: Lane, rank: usize, v0: f64, v1: f64, bytes: u64) {
    if !enabled(kind) {
        return;
    }
    TLS.with(|t| {
        let mut b = t.borrow_mut();
        if let Some(c) = b.as_mut() {
            c.push(Span {
                kind,
                lane,
                rank: rank as u32,
                step: 0,
                depth: 0,
                bytes,
                label: None,
                wall0: f64::NAN,
                wall1: f64::NAN,
                virt0: v0,
                virt1: v1,
            });
        }
    });
}

/// Publish the rank's virtual clock to the tracing layer (monotonic max).
/// The virtual fabric calls this whenever its per-rank clock advances, so
/// spans opened afterwards carry virtual stamps.
#[inline]
pub fn vclock(t: f64) {
    if lvl() == 0 {
        return;
    }
    TLS.with(|tl| {
        if let Some(c) = tl.borrow_mut().as_mut() {
            // NaN-aware max: the first stamp always lands
            if !(t <= c.vnow) {
                c.vnow = t;
            }
        }
    });
}

/// Bump a named registry counter. Handle resolution is cached per thread;
/// steady state is one relaxed atomic add.
#[inline]
pub fn count(name: &'static str, n: u64) {
    if lvl() == 0 {
        return;
    }
    TLS.with(|t| {
        let mut b = t.borrow_mut();
        if let Some(c) = b.as_mut() {
            let reg = &c.tracer.registry;
            c.counters.entry(name).or_insert_with(|| reg.counter(name)).add(n);
        }
    });
}

/// Record a value into a named registry histogram.
#[inline]
pub fn observe(name: &'static str, v: f64) {
    if lvl() == 0 {
        return;
    }
    TLS.with(|t| {
        let mut b = t.borrow_mut();
        if let Some(c) = b.as_mut() {
            let reg = &c.tracer.registry;
            c.hists.entry(name).or_insert_with(|| reg.histogram(name)).observe(v);
        }
    });
}

/// Merge this thread's span buffer into the tracer sink. Cold path —
/// called once per step by the rank loop (and by guard drops).
pub fn flush() {
    TLS.with(|t| {
        let mut b = t.borrow_mut();
        if let Some(c) = b.as_mut() {
            let mut buf = std::mem::take(&mut c.buf);
            c.tracer.record_all(&mut buf);
        }
    });
}

/// The current thread's tracer binding, for handing to a helper thread
/// (e.g. the pipeline's overlapped encoder): the helper re-installs it
/// with [`Tracer::install`] so its spans land on the same rank.
pub fn scope() -> Option<(Arc<Tracer>, usize)> {
    if lvl() == 0 {
        return None;
    }
    TLS.with(|t| t.borrow().as_ref().map(|c| (c.tracer.clone(), c.rank as usize)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn off_level_records_nothing() {
        let tracer = Tracer::new(TraceLevel::Off, 1);
        {
            let _g = tracer.install(0);
            let mut s = span(SpanKind::Compute);
            assert!(!s.live());
            s.label_with(|| panic!("label closure must not run when dead"));
            count("x", 1);
            observe("y", 1.0);
            vclock(5.0);
        }
        assert!(tracer.drain(0).is_empty());
        assert_eq!(tracer.registry().counter("x").get(), 0);
    }

    #[test]
    fn step_level_filters_detail_kinds() {
        let tracer = Tracer::new(TraceLevel::Step, 1);
        {
            let _g = tracer.install(0);
            drop(span(SpanKind::Compute)); // step-level: recorded
            drop(span(SpanKind::Pack)); // full-level: dropped
            flush();
        }
        let spans = tracer.drain(0);
        assert_eq!(spans.len(), 1);
        assert_eq!(spans[0].kind, SpanKind::Compute);
    }

    #[test]
    fn spans_nest_and_stamp_both_clocks() {
        let tracer = Tracer::new(TraceLevel::Full, 2);
        {
            let _g = tracer.install(1);
            vclock(10.0);
            {
                let mut outer = span(SpanKind::Exchange);
                outer.label_with(|| "outer".to_string());
                {
                    let mut inner = span(SpanKind::Pack);
                    inner.set_bytes(128);
                    vclock(12.5);
                }
            }
            flush();
        }
        let spans = tracer.drain(3);
        assert_eq!(spans.len(), 2);
        // children buffer before parents; drain orders by start time
        let outer = spans.iter().find(|s| s.kind == SpanKind::Exchange).unwrap();
        let inner = spans.iter().find(|s| s.kind == SpanKind::Pack).unwrap();
        assert_eq!(outer.depth, 0);
        assert_eq!(inner.depth, 1);
        assert_eq!(outer.rank, 1);
        assert_eq!(outer.step, 3);
        assert_eq!(inner.bytes, 128);
        assert_eq!(outer.label.as_deref(), Some("outer"));
        assert!((outer.virt0 - 10.0).abs() < 1e-12);
        assert!((outer.virt1 - 12.5).abs() < 1e-12);
        assert!(outer.has_wall());
        assert!(outer.wall_dur() >= inner.wall_dur());
        check_nesting(&spans).unwrap();
    }

    #[test]
    fn install_nests_and_restores() {
        let tracer = Tracer::new(TraceLevel::Full, 2);
        let _outer = tracer.install(0);
        {
            let _inner = tracer.install(1);
            drop(span(SpanKind::Encode));
        }
        // back on rank 0
        drop(span(SpanKind::Sparsify));
        flush();
        let spans = tracer.drain(0);
        assert_eq!(spans.len(), 2);
        assert_eq!(spans.iter().find(|s| s.kind == SpanKind::Encode).unwrap().rank, 1);
        assert_eq!(spans.iter().find(|s| s.kind == SpanKind::Sparsify).unwrap().rank, 0);
    }

    #[test]
    fn registry_counts_via_tls_cache() {
        let tracer = Tracer::new(TraceLevel::Full, 1);
        {
            let _g = tracer.install(0);
            count("wire.bytes", 100);
            count("wire.bytes", 50);
            observe("merge.nnz", 32.0);
        }
        assert_eq!(tracer.registry().counter("wire.bytes").get(), 150);
        assert_eq!(tracer.registry().histogram("merge.nnz").count(), 1);
    }

    #[test]
    fn port_span_lands_on_port_lane() {
        let tracer = Tracer::new(TraceLevel::Full, 1);
        {
            let _g = tracer.install(0);
            port_span(SpanKind::Send, Lane::egress(1), 2.0, 3.5, 4096);
            flush();
        }
        let spans = tracer.drain(0);
        assert_eq!(spans.len(), 1);
        assert_eq!(spans[0].lane, Lane::EgressInter);
        assert!((spans[0].virt_dur() - 1.5).abs() < 1e-12);
        assert_eq!(spans[0].bytes, 4096);
        // wall extent is a point (the booking instant)
        assert_eq!(spans[0].wall0, spans[0].wall1);
    }

    #[test]
    fn virtual_span_carries_explicit_rank_and_no_wall() {
        let tracer = Tracer::new(TraceLevel::Full, 8);
        {
            // collector installed for rank 0, span recorded for rank 5
            let _g = tracer.install(0);
            virtual_span(SpanKind::Recv, Lane::ingress(0), 5, 1.0, 2.25, 512);
            flush();
        }
        let spans = tracer.drain(0);
        assert_eq!(spans.len(), 1);
        assert_eq!(spans[0].rank, 5);
        assert_eq!(spans[0].lane, Lane::IngressIntra);
        assert!(!spans[0].has_wall());
        assert!((spans[0].virt_dur() - 1.25).abs() < 1e-12);
        assert_eq!(spans[0].bytes, 512);
    }

    #[test]
    fn trace_level_parse() {
        assert_eq!(TraceLevel::parse("off").unwrap(), TraceLevel::Off);
        assert_eq!(TraceLevel::parse("step").unwrap(), TraceLevel::Step);
        assert_eq!(TraceLevel::parse("full").unwrap(), TraceLevel::Full);
        assert_eq!(TraceLevel::parse("sampled").unwrap(), TraceLevel::Sampled);
        assert!(TraceLevel::parse("verbose").is_err());
        assert_eq!(TraceLevel::Full.name(), "full");
        assert_eq!(TraceLevel::Sampled.name(), "sampled");
    }

    #[test]
    fn sampled_level_folds_and_retains_only_exemplars() {
        let tracer = Tracer::new(TraceLevel::Sampled, 32);
        {
            let _g = tracer.install(0);
            // detail kinds are observed (not filtered like step level)
            for rank in 0..32 {
                virtual_span(SpanKind::RecvWait, Lane::Cpu, rank, 0.0, 1e-3, 0);
            }
            flush();
        }
        // synthesized spans go through the same fold
        for rank in 0..32u32 {
            tracer.record(Span {
                kind: SpanKind::Compute,
                lane: Lane::Cpu,
                rank,
                step: 0,
                depth: 0,
                bytes: 0,
                label: None,
                wall0: f64::NAN,
                wall1: f64::NAN,
                virt0: 0.0,
                virt1: if rank == 9 { 8e-3 } else { 1e-3 },
            });
        }
        tracer.end_health_step(0, 1e-2, (0.0, 1e-2), None);
        // only rank 0 (the pre-marked exemplar) kept spans this step
        let spans = tracer.drain(0);
        assert!(!spans.is_empty());
        assert!(spans.iter().all(|s| s.rank == 0), "non-exemplar spans leaked");
        let health = tracer.take_health().expect("sampled tracer owns an aggregator");
        let st = &health.steps()[0];
        assert_eq!(st.spans_folded, 64);
        assert_eq!(st.flagged, vec![9], "the slow rank is flagged from the aggregate");
        assert!(health.is_exemplar(9), "flagged rank becomes an exemplar");
        assert_eq!(tracer.take_health().map(|_| ()), None, "take_health drains");
    }
}
