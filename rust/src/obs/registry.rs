//! Metrics registry: named counters and log2-binned histograms.
//!
//! The registry is the durable, queryable side of the obs subsystem: spans
//! answer "where did the time go", the registry answers "how much of what
//! happened" (bytes per link class, codec invocations, merge output
//! sizes, egress backlog). Handles are `Arc`-backed atomics, so the
//! instrumented hot path pays one relaxed atomic op per event; the name →
//! handle map is only locked when a handle is first resolved (the
//! thread-local collector in [`crate::obs`] caches handles per thread).

use super::health::{hist_bin, hist_bin_edge, HIST_BINS};
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Monotonic counter handle. Cloning shares the underlying cell.
#[derive(Clone, Default)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

struct HistInner {
    count: AtomicU64,
    /// f64 bits, CAS-accumulated.
    sum_bits: AtomicU64,
    /// f64 bits of the max observed value.
    max_bits: AtomicU64,
    bins: [AtomicU64; HIST_BINS],
}

/// Lock-free histogram handle with power-of-two bins.
#[derive(Clone)]
pub struct Histogram(Arc<HistInner>);

impl Default for Histogram {
    fn default() -> Self {
        Histogram(Arc::new(HistInner {
            count: AtomicU64::new(0),
            sum_bits: AtomicU64::new(0f64.to_bits()),
            max_bits: AtomicU64::new(f64::NEG_INFINITY.to_bits()),
            bins: std::array::from_fn(|_| AtomicU64::new(0)),
        }))
    }
}

impl Histogram {
    pub fn observe(&self, v: f64) {
        let h = &*self.0;
        h.count.fetch_add(1, Ordering::Relaxed);
        h.bins[hist_bin(v)].fetch_add(1, Ordering::Relaxed);
        // CAS loops: contention here is per-thread-rare (one event per
        // encode/merge/send), not per-element.
        let _ = h.sum_bits.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |bits| {
            Some((f64::from_bits(bits) + v).to_bits())
        });
        let _ = h.max_bits.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |bits| {
            if v > f64::from_bits(bits) { Some(v.to_bits()) } else { None }
        });
    }

    pub fn count(&self) -> u64 {
        self.0.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> f64 {
        f64::from_bits(self.0.sum_bits.load(Ordering::Relaxed))
    }

    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 { f64::NAN } else { self.sum() / n as f64 }
    }

    pub fn max(&self) -> f64 {
        let m = f64::from_bits(self.0.max_bits.load(Ordering::Relaxed));
        if self.count() == 0 { f64::NAN } else { m }
    }

    /// Approximate quantile: the upper edge of the fixed log-bucket bin
    /// (shared layout in [`crate::obs::health`]) where the cumulative
    /// count crosses `q` (within 2x of the true value). Because the bin
    /// edges are fixed, quantiles are invariant under [`Self::merge`]
    /// order — merged shards answer exactly what one big histogram would.
    pub fn quantile(&self, q: f64) -> f64 {
        let n = self.count();
        if n == 0 {
            return f64::NAN;
        }
        let target = (q.clamp(0.0, 1.0) * n as f64).ceil().max(1.0) as u64;
        let mut acc = 0u64;
        for i in 0..HIST_BINS {
            acc += self.0.bins[i].load(Ordering::Relaxed);
            if acc >= target {
                return hist_bin_edge(i);
            }
        }
        self.max()
    }

    /// Fold `other` into `self`: elementwise bin add, count/sum add,
    /// max-of-max. Associative and commutative on counts, bins, and max
    /// (the f64 `sum` is order-sensitive only in the last ulp), so
    /// per-rank shards can be merged in any order.
    pub fn merge(&self, other: &Histogram) {
        let (a, b) = (&*self.0, &*other.0);
        let n = b.count.load(Ordering::Relaxed);
        if n == 0 {
            return;
        }
        a.count.fetch_add(n, Ordering::Relaxed);
        for (ab, bb) in a.bins.iter().zip(&b.bins) {
            let c = bb.load(Ordering::Relaxed);
            if c > 0 {
                ab.fetch_add(c, Ordering::Relaxed);
            }
        }
        let bsum = f64::from_bits(b.sum_bits.load(Ordering::Relaxed));
        let _ = a.sum_bits.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |bits| {
            Some((f64::from_bits(bits) + bsum).to_bits())
        });
        let bmax = f64::from_bits(b.max_bits.load(Ordering::Relaxed));
        let _ = a.max_bits.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |bits| {
            if bmax > f64::from_bits(bits) { Some(bmax.to_bits()) } else { None }
        });
    }
}

/// Name → handle registry shared by all ranks of one trainer/bench run.
#[derive(Default)]
pub struct MetricsRegistry {
    counters: Mutex<BTreeMap<String, Counter>>,
    hists: Mutex<BTreeMap<String, Histogram>>,
}

impl MetricsRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Resolve (or create) a counter handle. Locks the map; callers on hot
    /// paths should cache the handle.
    pub fn counter(&self, name: &str) -> Counter {
        self.counters.lock().unwrap().entry(name.to_string()).or_default().clone()
    }

    /// Resolve (or create) a histogram handle.
    pub fn histogram(&self, name: &str) -> Histogram {
        self.hists.lock().unwrap().entry(name.to_string()).or_default().clone()
    }

    /// Snapshot every metric as JSON:
    /// `{"counters": {name: n}, "histograms": {name: {count, sum, mean, max, p50}}}`.
    pub fn snapshot(&self) -> Json {
        let mut counters = BTreeMap::new();
        for (name, c) in self.counters.lock().unwrap().iter() {
            counters.insert(name.clone(), Json::Num(c.get() as f64));
        }
        let mut hists = BTreeMap::new();
        for (name, h) in self.hists.lock().unwrap().iter() {
            let mut m = BTreeMap::new();
            m.insert("count".to_string(), Json::Num(h.count() as f64));
            m.insert("sum".to_string(), Json::Num(h.sum()));
            m.insert("mean".to_string(), finite_or_null(h.mean()));
            m.insert("max".to_string(), finite_or_null(h.max()));
            m.insert("p50".to_string(), finite_or_null(h.quantile(0.5)));
            hists.insert(name.clone(), Json::Obj(m));
        }
        let mut top = BTreeMap::new();
        top.insert("counters".to_string(), Json::Obj(counters));
        top.insert("histograms".to_string(), Json::Obj(hists));
        Json::Obj(top)
    }
}

fn finite_or_null(x: f64) -> Json {
    if x.is_finite() { Json::Num(x) } else { Json::Null }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_accumulates_and_shares() {
        let r = MetricsRegistry::new();
        let a = r.counter("bytes");
        let b = r.counter("bytes");
        a.add(3);
        b.add(4);
        assert_eq!(r.counter("bytes").get(), 7);
        assert_eq!(r.counter("other").get(), 0);
    }

    #[test]
    fn histogram_stats() {
        let r = MetricsRegistry::new();
        let h = r.histogram("merge.nnz");
        for v in [1.0, 2.0, 4.0, 1024.0] {
            h.observe(v);
        }
        assert_eq!(h.count(), 4);
        assert!((h.sum() - 1031.0).abs() < 1e-9);
        assert!((h.max() - 1024.0).abs() < 1e-9);
        // p50 lands in the bin containing 2.0 → upper edge 4.0
        assert!(h.quantile(0.5) <= 4.0 + 1e-9);
        assert!(h.quantile(1.0) >= 1024.0);
    }

    #[test]
    fn histogram_handles_edge_values() {
        let h = Histogram::default();
        h.observe(0.0);
        h.observe(-1.0);
        h.observe(f64::NAN);
        h.observe(1e-12); // below the smallest bin: clamps, doesn't panic
        h.observe(1e300); // above the largest bin: clamps
        assert_eq!(h.count(), 5);
    }

    #[test]
    fn empty_histogram_is_nan_not_panic() {
        let h = Histogram::default();
        assert!(h.mean().is_nan());
        assert!(h.max().is_nan());
        assert!(h.quantile(0.5).is_nan());
    }

    #[test]
    fn merged_shards_answer_like_one_histogram_in_any_order() {
        // 240 values spread over ~14 decades, dealt round-robin into 5
        // per-rank shards, merged in two different permutations: both
        // merge orders must report bit-identical quantiles/max/count,
        // equal to the single-histogram answer (shared fixed-bin layout
        // makes merge associative and commutative).
        let values: Vec<f64> = (0..240).map(|i| (i as f64 * 0.19 - 23.0).exp2()).collect();
        let whole = Histogram::default();
        let shards: Vec<Histogram> = (0..5).map(|_| Histogram::default()).collect();
        for (i, &v) in values.iter().enumerate() {
            whole.observe(v);
            shards[i % 5].observe(v);
        }
        let forward = Histogram::default();
        for s in &shards {
            forward.merge(s);
        }
        let backward = Histogram::default();
        for s in shards.iter().rev() {
            backward.merge(s);
        }
        for q in [0.0, 0.25, 0.5, 0.9, 0.99, 1.0] {
            let want = whole.quantile(q);
            assert_eq!(forward.quantile(q).to_bits(), want.to_bits(), "q={q}");
            assert_eq!(backward.quantile(q).to_bits(), want.to_bits(), "q={q}");
        }
        assert_eq!(forward.count(), whole.count());
        assert_eq!(backward.count(), whole.count());
        assert_eq!(forward.max().to_bits(), whole.max().to_bits());
        assert_eq!(backward.max().to_bits(), whole.max().to_bits());
        // merging an empty shard is a no-op
        forward.merge(&Histogram::default());
        assert_eq!(forward.count(), whole.count());
    }

    #[test]
    fn snapshot_shape() {
        let r = MetricsRegistry::new();
        r.counter("a").add(1);
        r.histogram("b").observe(2.0);
        let j = r.snapshot();
        assert_eq!(j.get("counters").unwrap().get("a").unwrap().as_usize(), Some(1));
        let b = j.get("histograms").unwrap().get("b").unwrap();
        assert_eq!(b.get("count").unwrap().as_usize(), Some(1));
        // round-trips through the repo's own parser
        let s = j.to_string();
        assert_eq!(Json::parse(&s).unwrap(), j);
    }
}
