//! Fleet-health primitives: the shared fixed log-bucket histogram layout,
//! plain mergeable histograms, the time-class taxonomy, and the robust
//! (MAD-based) outlier detector.
//!
//! This module holds the *math* of the telemetry plane; the streaming
//! aggregator that applies it per step lives in [`crate::obs::fleet`].
//!
//! # Fixed log-bucket layout
//!
//! Every histogram in the repo — the lock-free registry
//! [`crate::obs::registry::Histogram`] and the plain [`FixedHistogram`]
//! here — bins values into the **same** fixed layout ([`hist_bin`] /
//! [`hist_bin_edge`]): bin 0 holds non-positive and non-finite values,
//! bin `i` (1..=65) holds `2^(i-34) <= v < 2^(i-33)`, covering ~1e-10
//! (sub-ns waits) through ~4e9 (multi-GB byte sizes). Because the layout
//! is fixed and data-independent, merging two histograms is an
//! element-wise add of bin counts — **associative and commutative** — so
//! per-rank shards can be folded in any grouping or order and every
//! quantile read off the merged bins is identical. That is the property
//! fleet-scale aggregation needs: 10k ranks fold locally, the aggregator
//! merges, and `p99(merge(a, b)) == p99(merge(b, a))` exactly.
//!
//! # Detector math
//!
//! Per step and metric (compute seconds, recv-wait seconds) the detector
//! computes the fleet median `m` and the scaled median absolute
//! deviation `MAD` (1.4826·median(|x−m|), normal-consistent), and flags
//! rank `r` when
//!
//! ```text
//! x_r > m + max(6 · MAD, 0.3 · m)
//! ```
//!
//! The `6·MAD` term is the usual robust z-score gate; the `0.3·m`
//! relative floor keeps a degenerate fleet (MAD = 0 because all but one
//! rank are identical — exactly the injected-straggler corpus) from
//! flagging ranks a few ulps above the median. A 1.5× straggler clears
//! the floor (`1.5m > 1.3m`); uniform fleets flag nothing.

use crate::obs::span::SpanKind;
use crate::util::json::Json;
use crate::util::stats::{mad, median};
use std::collections::BTreeMap;

/// Number of bins in the shared fixed log-bucket layout.
pub const HIST_BINS: usize = 66;
/// Bin-edge exponent offset: bin `i >= 1` has upper edge `2^(i - HIST_BIN_OFFSET)`.
pub const HIST_BIN_OFFSET: i32 = 33;

/// Bin index of `v` in the shared layout (bin 0 = non-positive/non-finite).
#[inline]
pub fn hist_bin(v: f64) -> usize {
    if v <= 0.0 || !v.is_finite() {
        0
    } else {
        (v.log2().floor() as i32 + HIST_BIN_OFFSET + 1).clamp(1, HIST_BINS as i32 - 1) as usize
    }
}

/// Upper edge of bin `i` (inclusive-exclusive binning; edge of bin 0 is 0).
#[inline]
pub fn hist_bin_edge(i: usize) -> f64 {
    if i == 0 { 0.0 } else { 2f64.powi(i as i32 - HIST_BIN_OFFSET) }
}

/// Plain (non-atomic) histogram over the shared fixed log-bucket layout.
///
/// This is the single-writer counterpart of the registry
/// [`crate::obs::registry::Histogram`]: same bins, same quantile rule, but
/// owned data — the fleet aggregator folds millions of spans per step
/// through [`FixedHistogram::observe`], so it must cost a handful of adds,
/// not atomics. [`FixedHistogram::merge`] is associative (see module docs).
#[derive(Clone, Debug)]
pub struct FixedHistogram {
    count: u64,
    sum: f64,
    max: f64,
    bins: [u64; HIST_BINS],
}

impl Default for FixedHistogram {
    fn default() -> Self {
        FixedHistogram { count: 0, sum: 0.0, max: f64::NEG_INFINITY, bins: [0; HIST_BINS] }
    }
}

impl FixedHistogram {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn observe(&mut self, v: f64) {
        self.count += 1;
        self.bins[hist_bin(v)] += 1;
        self.sum += v;
        if v > self.max {
            self.max = v;
        }
    }

    /// Element-wise merge of another shard into this one. Counts, bins and
    /// max merge exactly in any order/grouping; `sum` is an f64
    /// accumulation (last-ulp order sensitivity, quantiles unaffected).
    pub fn merge(&mut self, other: &FixedHistogram) {
        self.count += other.count;
        self.sum += other.sum;
        if other.count > 0 && other.max > self.max {
            self.max = other.max;
        }
        for (a, b) in self.bins.iter_mut().zip(&other.bins) {
            *a += b;
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 { f64::NAN } else { self.sum / self.count as f64 }
    }

    pub fn max(&self) -> f64 {
        if self.count == 0 { f64::NAN } else { self.max }
    }

    /// Approximate quantile: the upper edge of the bin where the cumulative
    /// count crosses `q` (same rule as the registry histogram, so merged
    /// shards and live handles agree bit-for-bit).
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        let target = (q.clamp(0.0, 1.0) * self.count as f64).ceil().max(1.0) as u64;
        let mut acc = 0u64;
        for (i, &b) in self.bins.iter().enumerate() {
            acc += b;
            if acc >= target {
                return hist_bin_edge(i);
            }
        }
        self.max()
    }

    /// `{count, sum, mean, max, p50, p90, p99, bins: [[bin, count], ...]}`
    /// with only non-empty bins listed (sparse, bounded, order-stable).
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("count".to_string(), Json::Num(self.count as f64));
        m.insert("sum".to_string(), finite_or_null(self.sum));
        m.insert("mean".to_string(), finite_or_null(self.mean()));
        m.insert("max".to_string(), finite_or_null(self.max()));
        m.insert("p50".to_string(), finite_or_null(self.quantile(0.50)));
        m.insert("p90".to_string(), finite_or_null(self.quantile(0.90)));
        m.insert("p99".to_string(), finite_or_null(self.quantile(0.99)));
        let bins: Vec<Json> = self
            .bins
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| Json::Arr(vec![Json::Num(i as f64), Json::Num(c as f64)]))
            .collect();
        m.insert("bins".to_string(), Json::Arr(bins));
        Json::Obj(m)
    }
}

fn finite_or_null(x: f64) -> Json {
    if x.is_finite() { Json::Num(x) } else { Json::Null }
}

/// The five time classes the fleet percentile series is reported over.
/// Span kinds that don't advance a rank's timeline (send/recv port
/// bookings, decode/merge interiors) are counted elsewhere (byte
/// counters) and carry no class.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TimeClass {
    Compute,
    Encode,
    Exchange,
    RecvWait,
    Barrier,
}

impl TimeClass {
    pub const ALL: [TimeClass; 5] = [
        TimeClass::Compute,
        TimeClass::Encode,
        TimeClass::Exchange,
        TimeClass::RecvWait,
        TimeClass::Barrier,
    ];

    pub fn name(self) -> &'static str {
        match self {
            TimeClass::Compute => "compute",
            TimeClass::Encode => "encode",
            TimeClass::Exchange => "exchange",
            TimeClass::RecvWait => "recv_wait",
            TimeClass::Barrier => "barrier",
        }
    }

    /// Index into a `[T; 5]` keyed by [`TimeClass::ALL`] order.
    #[inline]
    pub fn idx(self) -> usize {
        self as usize
    }

    /// The class a span kind folds into (`None` = not a timeline class).
    #[inline]
    pub fn of_kind(kind: SpanKind) -> Option<TimeClass> {
        match kind {
            SpanKind::Compute => Some(TimeClass::Compute),
            SpanKind::Encode | SpanKind::Pack | SpanKind::Sparsify => Some(TimeClass::Encode),
            SpanKind::Exchange => Some(TimeClass::Exchange),
            SpanKind::RecvWait => Some(TimeClass::RecvWait),
            SpanKind::Barrier => Some(TimeClass::Barrier),
            _ => None,
        }
    }
}

/// Robust outlier threshold over a fleet of per-rank values (see module
/// docs for the rule). Returns `+inf` (nothing can be flagged) when the
/// fleet is too small for robust statistics (< 4 values) or the median is
/// not positive (no signal to be an outlier against).
pub fn robust_threshold(values: &[f64]) -> f64 {
    if values.len() < 4 {
        return f64::INFINITY;
    }
    let m = median(values);
    if !(m > 0.0) {
        return f64::INFINITY;
    }
    m + (6.0 * mad(values)).max(0.3 * m)
}

/// Indices of values strictly above [`robust_threshold`], ascending.
pub fn robust_flags(values: &[f64]) -> Vec<usize> {
    let thr = robust_threshold(values);
    values.iter().enumerate().filter(|&(_, &v)| v > thr).map(|(i, _)| i).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bin_layout_covers_edges() {
        assert_eq!(hist_bin(0.0), 0);
        assert_eq!(hist_bin(-1.0), 0);
        assert_eq!(hist_bin(f64::NAN), 0);
        assert_eq!(hist_bin(1e-300), 1, "underflow clamps to the first bin");
        assert_eq!(hist_bin(1e300), HIST_BINS - 1, "overflow clamps to the last bin");
        // a value lands strictly below its bin's upper edge
        for v in [1e-9, 1e-3, 0.5, 1.0, 3.0, 1e6] {
            let b = hist_bin(v);
            assert!(v <= hist_bin_edge(b), "v={v} bin={b} edge={}", hist_bin_edge(b));
            assert!(b == 1 || v >= hist_bin_edge(b - 1), "v={v} below lower edge");
        }
    }

    #[test]
    fn fixed_histogram_tracks_stats_and_quantiles() {
        let mut h = FixedHistogram::new();
        for v in [1.0, 2.0, 4.0, 1024.0] {
            h.observe(v);
        }
        assert_eq!(h.count(), 4);
        assert!((h.sum() - 1031.0).abs() < 1e-9);
        assert!((h.max() - 1024.0).abs() < 1e-9);
        assert!(h.quantile(0.5) <= 4.0 + 1e-9);
        assert!(h.quantile(1.0) >= 1024.0);
        let empty = FixedHistogram::new();
        assert!(empty.mean().is_nan());
        assert!(empty.quantile(0.5).is_nan());
    }

    #[test]
    fn merge_is_associative_and_order_independent() {
        let values: Vec<f64> = (0..300).map(|i| (i as f64 * 0.37).exp2() * 1e-6).collect();
        // one histogram observing everything, versus shards merged in
        // permuted orders and groupings
        let mut whole = FixedHistogram::new();
        for &v in &values {
            whole.observe(v);
        }
        let shard = |range: std::ops::Range<usize>| {
            let mut h = FixedHistogram::new();
            for &v in &values[range] {
                h.observe(v);
            }
            h
        };
        let (a, b, c) = (shard(0..100), shard(100..180), shard(180..300));
        let mut ab_c = a.clone();
        ab_c.merge(&b);
        ab_c.merge(&c);
        let mut c_ba = c.clone();
        let mut ba = b.clone();
        ba.merge(&a);
        c_ba.merge(&ba);
        for h in [&ab_c, &c_ba] {
            assert_eq!(h.count(), whole.count());
            assert_eq!(h.max().to_bits(), whole.max().to_bits());
            for q in [0.0, 0.25, 0.5, 0.9, 0.99, 1.0] {
                assert_eq!(h.quantile(q).to_bits(), whole.quantile(q).to_bits(), "q={q}");
            }
        }
        assert_eq!(ab_c.bins, c_ba.bins);
    }

    #[test]
    fn time_class_maps_kinds() {
        assert_eq!(TimeClass::of_kind(SpanKind::Compute), Some(TimeClass::Compute));
        assert_eq!(TimeClass::of_kind(SpanKind::Pack), Some(TimeClass::Encode));
        assert_eq!(TimeClass::of_kind(SpanKind::RecvWait), Some(TimeClass::RecvWait));
        assert_eq!(TimeClass::of_kind(SpanKind::Send), None);
        assert_eq!(TimeClass::of_kind(SpanKind::Merge), None);
        for (i, c) in TimeClass::ALL.iter().enumerate() {
            assert_eq!(c.idx(), i);
        }
    }

    #[test]
    fn detector_flags_stragglers_not_uniform_fleets() {
        let b = 2e-3;
        // uniform fleet: MAD = 0, relative floor holds → nothing flagged
        let uniform = vec![b; 8];
        assert!(robust_flags(&uniform).is_empty());
        // injected 2.0× and 1.5× stragglers at ranks 0 and 4
        let mut v = vec![b; 8];
        v[0] = 2.0 * b;
        v[4] = 1.5 * b;
        assert_eq!(robust_flags(&v), vec![0, 4]);
        // tiny fleets and zero-signal fleets never flag
        assert!(robust_flags(&[b, 10.0 * b]).is_empty());
        assert!(robust_flags(&[0.0; 8]).is_empty());
        // genuinely spread fleet: MAD term dominates, median-ish values safe
        let spread: Vec<f64> = (0..16).map(|i| b * (1.0 + 0.02 * i as f64)).collect();
        assert!(robust_flags(&spread).is_empty());
    }
}
