//! Streaming fleet telemetry: per-step aggregation, straggler/anomaly
//! detection, and exemplar trace sampling (`--trace sampled`).
//!
//! PR 6's per-rank Chrome traces are intractable at fleetsim rank counts
//! (10k ranks × full-span lanes is hundreds of MB per step). This module
//! is the bounded alternative: under [`crate::obs::TraceLevel::Sampled`]
//! every span is **folded** into a [`FleetTelemetry`] aggregate at record
//! time — per-rank time totals plus fleet-wide [`FixedHistogram`]s per
//! [`TimeClass`] — and only the spans of at most K *exemplar ranks*
//! (always rank 0, plus the per-step slowest rank and every flagged
//! anomaly, first-come capped at K) are retained for the Perfetto trace.
//! Memory and artifact size are therefore O(K + histograms) per step, not
//! O(ranks × spans).
//!
//! At the end of each step [`FleetTelemetry::end_step`] freezes the
//! aggregate into a [`StepHealth`] snapshot, runs the robust MAD detector
//! (see [`crate::obs::health`] for the math) over per-rank compute and
//! recv-wait seconds, detects crash windows (ranks with zero telemetry
//! while peers report), and cross-checks every flag against the injected
//! [`Scenario`] to attribute a cause. [`FleetTelemetry::report`] then
//! assembles the schema-versioned `HEALTH_<name>.json` artifact
//! ([`HealthReport`]) with the per-step percentile series, the
//! flagged-rank log, and the exemplar-trace section.

use super::health::{robust_threshold, FixedHistogram, TimeClass};
use super::span::{Lane, Span, SpanKind};
use crate::util::json::Json;
use crate::util::stats::median;
use crate::vfabric::Scenario;
use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::path::PathBuf;

/// Schema version for `HEALTH_*.json` artifacts (see also
/// [`super::export::TRACE_SCHEMA_VERSION`] for `TRACE_*.json`).
pub const HEALTH_SCHEMA_VERSION: u32 = 1;

/// Default exemplar budget K: full traces are retained for at most this
/// many distinct ranks over a run.
pub const DEFAULT_EXEMPLARS: usize = 8;

/// Per-rank running totals for the step being folded.
#[derive(Clone, Copy, Default)]
struct RankAccum {
    spans: u32,
    compute_s: f64,
    exchange_s: f64,
    recv_wait_s: f64,
    barrier_s: f64,
}

/// The in-flight aggregate of the current step.
struct StepAccum {
    class: [FixedHistogram; 5],
    per_rank: Vec<RankAccum>,
    intra_bytes: u64,
    inter_bytes: u64,
    folded: u64,
}

impl StepAccum {
    fn new(world: usize) -> Self {
        StepAccum {
            class: std::array::from_fn(|_| FixedHistogram::new()),
            per_rank: vec![RankAccum::default(); world],
            intra_bytes: 0,
            inter_bytes: 0,
            folded: 0,
        }
    }
}

/// The duration a span contributes to its time class. Clock-advancing
/// classes prefer the virtual extent (the modelled time the fleet
/// percentiles are about); encode-side work happens at a virtual instant
/// and is wall-measured. Missing clocks contribute 0 rather than NaN.
#[inline]
fn class_dur(s: &Span, class: TimeClass) -> f64 {
    let d = if class == TimeClass::Encode {
        if s.has_wall() { s.wall_dur() } else { s.virt_dur() }
    } else if s.has_virtual() {
        s.virt_dur()
    } else {
        s.wall_dur()
    };
    if d.is_finite() { d.max(0.0) } else { 0.0 }
}

/// One frozen step of fleet health: class histograms, detector output,
/// and byte totals. Produced by [`FleetTelemetry::end_step`].
pub struct StepHealth {
    pub step: u32,
    /// `measured_step_s` of the step (virtual seconds on the virtual
    /// fabrics, wall seconds on the instant fabric).
    pub measured_s: f64,
    /// Virtual-clock extent of the step (NaN without a virtual clock).
    pub virt0: f64,
    pub virt1: f64,
    class: [FixedHistogram; 5],
    /// The busiest present rank (compute + exchange/recv-wait), `None`
    /// when no rank reported any telemetry.
    pub slowest_rank: Option<u32>,
    /// Ranks whose compute seconds exceeded the robust threshold.
    pub flagged: Vec<u32>,
    /// Ranks whose recv-wait seconds exceeded the robust threshold.
    pub wait_flagged: Vec<u32>,
    /// Ranks with zero spans while at least one peer reported (crash
    /// candidates, cross-checked against the scenario in the flag log).
    pub absent: Vec<u32>,
    pub intra_bytes: u64,
    pub inter_bytes: u64,
    pub spans_folded: u64,
}

impl StepHealth {
    /// The step's histogram for one time class.
    pub fn class_hist(&self, c: TimeClass) -> &FixedHistogram {
        &self.class[c.idx()]
    }

    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("step".to_string(), Json::Num(self.step as f64));
        m.insert("measured_s".to_string(), finite_or_null(self.measured_s));
        m.insert("virt0".to_string(), finite_or_null(self.virt0));
        m.insert("virt1".to_string(), finite_or_null(self.virt1));
        m.insert(
            "slowest_rank".to_string(),
            self.slowest_rank.map_or(Json::Null, |r| Json::Num(r as f64)),
        );
        m.insert("flagged".to_string(), ranks_json(&self.flagged));
        m.insert("wait_flagged".to_string(), ranks_json(&self.wait_flagged));
        m.insert("absent".to_string(), ranks_json(&self.absent));
        m.insert("intra_bytes".to_string(), Json::Num(self.intra_bytes as f64));
        m.insert("inter_bytes".to_string(), Json::Num(self.inter_bytes as f64));
        m.insert("spans_folded".to_string(), Json::Num(self.spans_folded as f64));
        let mut classes = BTreeMap::new();
        for c in TimeClass::ALL {
            classes.insert(c.name().to_string(), self.class[c.idx()].to_json());
        }
        m.insert("classes".to_string(), Json::Obj(classes));
        Json::Obj(m)
    }
}

/// One detector flag: which rank, which metric, how far past the
/// threshold, and the attributed cause (cross-checked against the
/// injected [`Scenario`] — `expected` is true when the scenario explains
/// the anomaly).
pub struct RankFlag {
    pub step: u32,
    pub rank: u32,
    /// `"compute_s"`, `"recv_wait_s"`, or `"absent"`.
    pub metric: &'static str,
    pub value_s: f64,
    pub median_s: f64,
    pub threshold_s: f64,
    pub cause: String,
    pub expected: bool,
}

impl RankFlag {
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("step".to_string(), Json::Num(self.step as f64));
        m.insert("rank".to_string(), Json::Num(self.rank as f64));
        m.insert("metric".to_string(), Json::Str(self.metric.to_string()));
        m.insert("value_s".to_string(), finite_or_null(self.value_s));
        m.insert("median_s".to_string(), finite_or_null(self.median_s));
        m.insert("threshold_s".to_string(), finite_or_null(self.threshold_s));
        m.insert("cause".to_string(), Json::Str(self.cause.clone()));
        m.insert("expected".to_string(), Json::Bool(self.expected));
        Json::Obj(m)
    }
}

/// The streaming aggregator: fold spans in, freeze a [`StepHealth`] per
/// step, and decide which ranks' spans are worth retaining in full.
pub struct FleetTelemetry {
    world: usize,
    max_exemplars: usize,
    exemplar: Vec<bool>,
    n_exemplars: usize,
    cur: StepAccum,
    steps: Vec<StepHealth>,
    flags: Vec<RankFlag>,
}

impl FleetTelemetry {
    /// Aggregator for a `world`-rank fleet with the default exemplar
    /// budget ([`DEFAULT_EXEMPLARS`]); rank 0 is always an exemplar.
    pub fn new(world: usize) -> Self {
        Self::with_exemplars(world, DEFAULT_EXEMPLARS)
    }

    /// Aggregator with an explicit exemplar budget `k >= 1`.
    pub fn with_exemplars(world: usize, k: usize) -> Self {
        let max_exemplars = k.max(1);
        let mut exemplar = vec![false; world];
        if let Some(e0) = exemplar.get_mut(0) {
            *e0 = true;
        }
        FleetTelemetry {
            world,
            max_exemplars,
            exemplar,
            n_exemplars: 1.min(world),
            cur: StepAccum::new(world),
            steps: Vec::new(),
            flags: Vec::new(),
        }
    }

    pub fn world(&self) -> usize {
        self.world
    }

    /// Whether `rank`'s spans are currently retained in full.
    #[inline]
    pub fn is_exemplar(&self, rank: usize) -> bool {
        self.exemplar.get(rank).copied().unwrap_or(false)
    }

    /// Ranks whose spans are retained in full, ascending.
    pub fn exemplar_ranks(&self) -> Vec<u32> {
        (0..self.world).filter(|&r| self.exemplar[r]).map(|r| r as u32).collect()
    }

    /// Spans folded into the current (unfrozen) step so far.
    pub fn folded_spans(&self) -> u64 {
        self.cur.folded
    }

    fn mark_exemplar(&mut self, rank: usize) {
        if rank < self.world && !self.exemplar[rank] && self.n_exemplars < self.max_exemplars {
            self.exemplar[rank] = true;
            self.n_exemplars += 1;
        }
    }

    /// Fold one span into the current step's aggregate. Returns whether
    /// the span should **also** be retained verbatim (exemplar rank).
    /// This is the `--trace sampled` hot path: a class lookup, one
    /// histogram observe, and a few adds — `benches/codec_micro.rs`
    /// asserts it stays under 200 ns per span.
    #[inline]
    pub fn fold(&mut self, s: &Span) -> bool {
        let rank = s.rank as usize;
        if rank >= self.world {
            return true; // out-of-range rank: retain rather than lose data
        }
        self.cur.folded += 1;
        let acc = &mut self.cur.per_rank[rank];
        acc.spans += 1;
        match s.kind {
            SpanKind::Send => match s.lane {
                Lane::EgressIntra => self.cur.intra_bytes += s.bytes,
                Lane::EgressInter => self.cur.inter_bytes += s.bytes,
                _ => {}
            },
            kind => {
                if let Some(class) = TimeClass::of_kind(kind) {
                    let d = class_dur(s, class);
                    match class {
                        TimeClass::Compute => acc.compute_s += d,
                        TimeClass::Exchange => acc.exchange_s += d,
                        TimeClass::RecvWait => acc.recv_wait_s += d,
                        TimeClass::Barrier => acc.barrier_s += d,
                        TimeClass::Encode => {}
                    }
                    self.cur.class[class.idx()].observe(d);
                }
            }
        }
        self.exemplar[rank]
    }

    /// Freeze the current step: run the detector, log flags (with the
    /// scenario cross-check), update the exemplar set for the next step,
    /// and append the [`StepHealth`] snapshot. `virt` is the step's
    /// virtual-clock window (NaNs on the instant fabric).
    pub fn end_step(
        &mut self,
        step: u32,
        measured_s: f64,
        virt: (f64, f64),
        scenario: Option<&Scenario>,
    ) {
        let acc = std::mem::replace(&mut self.cur, StepAccum::new(self.world));
        let present: Vec<usize> =
            (0..self.world).filter(|&r| acc.per_rank[r].spans > 0).collect();
        let absent: Vec<u32> = if present.is_empty() {
            Vec::new()
        } else {
            (0..self.world)
                .filter(|&r| acc.per_rank[r].spans == 0)
                .map(|r| r as u32)
                .collect()
        };
        let compute: Vec<f64> = present.iter().map(|&r| acc.per_rank[r].compute_s).collect();
        let wait: Vec<f64> = present.iter().map(|&r| acc.per_rank[r].recv_wait_s).collect();
        let mut flagged = Vec::new();
        let mut wait_flagged = Vec::new();
        if !present.is_empty() {
            let (cthr, cmed) = (robust_threshold(&compute), median(&compute));
            let (wthr, wmed) = (robust_threshold(&wait), median(&wait));
            for (i, &r) in present.iter().enumerate() {
                if compute[i] > cthr {
                    flagged.push(r as u32);
                    let (cause, expected) = compute_cause(scenario, r, step as usize);
                    self.flags.push(RankFlag {
                        step,
                        rank: r as u32,
                        metric: "compute_s",
                        value_s: compute[i],
                        median_s: cmed,
                        threshold_s: cthr,
                        cause,
                        expected,
                    });
                }
                if wait[i] > wthr {
                    wait_flagged.push(r as u32);
                    let (cause, expected) = wait_cause(scenario, virt);
                    self.flags.push(RankFlag {
                        step,
                        rank: r as u32,
                        metric: "recv_wait_s",
                        value_s: wait[i],
                        median_s: wmed,
                        threshold_s: wthr,
                        cause,
                        expected,
                    });
                }
            }
        }
        for &r in &absent {
            let (cause, expected) = absent_cause(scenario, r as usize, step as usize);
            self.flags.push(RankFlag {
                step,
                rank: r,
                metric: "absent",
                value_s: f64::NAN,
                median_s: f64::NAN,
                threshold_s: f64::NAN,
                cause,
                expected,
            });
        }
        // the busiest present rank: compute plus whichever of exchange /
        // recv-wait the run instruments (exchange contains the waits when
        // both are present — see the attribution rule in obs::export)
        let slowest_rank = present
            .iter()
            .map(|&r| {
                let a = &acc.per_rank[r];
                let ex = if a.exchange_s > 0.0 { a.exchange_s } else { a.recv_wait_s };
                (r, a.compute_s + ex)
            })
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .map(|(r, _)| r as u32);
        // exemplars for the *next* step: the slowest rank and every
        // flagged anomaly, first-come, capped at K (rank 0 pre-marked)
        if let Some(r) = slowest_rank {
            self.mark_exemplar(r as usize);
        }
        for &r in flagged.iter().chain(&wait_flagged).chain(&absent) {
            self.mark_exemplar(r as usize);
        }
        self.steps.push(StepHealth {
            step,
            measured_s,
            virt0: virt.0,
            virt1: virt.1,
            class: acc.class,
            slowest_rank,
            flagged,
            wait_flagged,
            absent,
            intra_bytes: acc.intra_bytes,
            inter_bytes: acc.inter_bytes,
            spans_folded: acc.folded,
        });
    }

    /// Per-step snapshots frozen so far.
    pub fn steps(&self) -> &[StepHealth] {
        &self.steps
    }

    /// The flag log accumulated so far.
    pub fn flags(&self) -> &[RankFlag] {
        &self.flags
    }

    /// Assemble the exportable [`HealthReport`] (consumes the aggregator).
    pub fn report(self, name: &str, meta: BTreeMap<String, Json>) -> HealthReport {
        let mut run: [FixedHistogram; 5] = std::array::from_fn(|_| FixedHistogram::new());
        for st in &self.steps {
            for (r, h) in run.iter_mut().zip(&st.class) {
                r.merge(h);
            }
        }
        let exemplar_ranks = self.exemplar_ranks();
        let mut flagged_ranks: Vec<u32> =
            self.flags.iter().filter(|f| f.metric == "compute_s").map(|f| f.rank).collect();
        flagged_ranks.sort_unstable();
        flagged_ranks.dedup();
        HealthReport {
            name: name.to_string(),
            ranks: self.world,
            max_exemplars: self.max_exemplars,
            exemplar_ranks,
            flagged_ranks,
            steps: self.steps,
            flags: self.flags,
            run,
            meta,
        }
    }
}

fn compute_cause(scenario: Option<&Scenario>, rank: usize, step: usize) -> (String, bool) {
    match scenario {
        Some(s) => {
            let f = s.compute_factor(rank, step);
            if f > 1.0 + 1e-9 {
                (format!("straggler (scenario-confirmed, {f:.2}x compute)"), true)
            } else {
                ("compute outlier (not in injected scenario)".to_string(), false)
            }
        }
        None => ("compute outlier (no scenario to cross-check)".to_string(), false),
    }
}

fn wait_cause(scenario: Option<&Scenario>, virt: (f64, f64)) -> (String, bool) {
    let Some(s) = scenario else {
        return ("recv-wait outlier (no scenario to cross-check)".to_string(), false);
    };
    // a flap is blamed only when its window overlaps this step's virtual
    // extent (or the run has no virtual clock to compare against)
    let overlaps = |f: &crate::vfabric::LinkFlap| f.start_s < virt.1 && virt.0 < f.end_s;
    if let Some(f) = s
        .link_flaps
        .iter()
        .find(|f| !virt.0.is_finite() || !virt.1.is_finite() || overlaps(f))
    {
        (
            format!(
                "link flap (scenario-confirmed: node {} at {:.1}x over [{:.3}, {:.3})s)",
                f.node, f.factor, f.start_s, f.end_s
            ),
            true,
        )
    } else if !s.stragglers.is_empty() {
        let cause = "slow peer links (scenario-confirmed: straggler NICs run at beta/factor)";
        (cause.to_string(), true)
    } else if s.link_jitter > 0.0 || !s.node_mbps.is_empty() {
        ("link jitter/heterogeneity (scenario-confirmed)".to_string(), true)
    } else {
        ("recv-wait outlier (not in injected scenario)".to_string(), false)
    }
}

fn absent_cause(scenario: Option<&Scenario>, rank: usize, step: usize) -> (String, bool) {
    match scenario {
        Some(s) if !s.alive(rank, step) => ("crash window (scenario-confirmed)".to_string(), true),
        Some(_) => ("rank silent (not in injected scenario)".to_string(), false),
        None => ("rank silent (no scenario to cross-check)".to_string(), false),
    }
}

/// The exportable fleet-health artifact: per-step percentile series, the
/// flagged-rank log with attributed causes, run-level histograms, and the
/// exemplar-trace section. Written as `HEALTH_<name>.json`.
pub struct HealthReport {
    /// Artifact stem: written as `HEALTH_<name>.json`.
    pub name: String,
    pub ranks: usize,
    pub max_exemplars: usize,
    /// Ranks whose full traces were retained (`<= max_exemplars`).
    pub exemplar_ranks: Vec<u32>,
    /// Union of compute-flagged ranks across steps — the set CI compares
    /// against the injected `--straggler` ranks.
    pub flagged_ranks: Vec<u32>,
    pub steps: Vec<StepHealth>,
    pub flags: Vec<RankFlag>,
    run: [FixedHistogram; 5],
    /// Free-form run metadata (schedule, fabric, scenario knobs).
    pub meta: BTreeMap<String, Json>,
}

impl HealthReport {
    /// Prefix the artifact stem with a job identifier: the export lands
    /// at `HEALTH_<job>_<name>.json` and — because the exemplar pointer
    /// is formatted from the same stem — references
    /// `TRACE_<job>_<name>.json`, keeping the per-job artifact pair
    /// consistent. Concurrent service tenants never clobber each other.
    pub fn for_job(mut self, job: &str) -> Self {
        self.name = format!("{job}_{}", self.name);
        self.meta.insert("job".to_string(), Json::Str(job.to_string()));
        self
    }

    /// Run-level (step-merged) histogram for one time class.
    pub fn run_hist(&self, c: TimeClass) -> &FixedHistogram {
        &self.run[c.idx()]
    }

    pub fn to_json(&self) -> Json {
        let mut top = BTreeMap::new();
        top.insert("schema_version".to_string(), Json::Num(HEALTH_SCHEMA_VERSION as f64));
        top.insert("name".to_string(), Json::Str(self.name.clone()));
        top.insert("ranks".to_string(), Json::Num(self.ranks as f64));
        for (k, v) in &self.meta {
            top.insert(k.clone(), v.clone());
        }
        let mut ex = BTreeMap::new();
        ex.insert("k".to_string(), Json::Num(self.max_exemplars as f64));
        ex.insert("ranks".to_string(), ranks_json(&self.exemplar_ranks));
        ex.insert("trace".to_string(), Json::Str(format!("TRACE_{}.json", self.name)));
        top.insert("exemplar_trace".to_string(), Json::Obj(ex));
        top.insert("flagged_ranks".to_string(), ranks_json(&self.flagged_ranks));
        top.insert(
            "steps".to_string(),
            Json::Arr(self.steps.iter().map(StepHealth::to_json).collect()),
        );
        top.insert(
            "flags".to_string(),
            Json::Arr(self.flags.iter().map(RankFlag::to_json).collect()),
        );
        let mut hists = BTreeMap::new();
        for c in TimeClass::ALL {
            hists.insert(c.name().to_string(), self.run[c.idx()].to_json());
        }
        top.insert("histograms".to_string(), Json::Obj(hists));
        Json::Obj(top)
    }

    /// Write `HEALTH_<name>.json` at the repo root (next to the
    /// `TRACE_*.json` / `BENCH_*.json` artifacts) and return the path.
    pub fn write(&self) -> std::io::Result<PathBuf> {
        let root = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            .parent()
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("."));
        let path = root.join(format!("HEALTH_{}.json", self.name));
        std::fs::write(&path, self.to_json().to_string())?;
        Ok(path)
    }

    /// Terminal fleet-health report (`--health-summary`).
    pub fn summary(&self) -> String {
        let mut out = String::new();
        let folded: u64 = self.steps.iter().map(|s| s.spans_folded).sum();
        let _ = writeln!(
            out,
            "health '{}': {} rank(s), {} step(s), {} span(s) folded, \
             flagged ranks {:?}, exemplars {:?} (k={})",
            self.name,
            self.ranks,
            self.steps.len(),
            folded,
            self.flagged_ranks,
            self.exemplar_ranks,
            self.max_exemplars,
        );
        for st in &self.steps {
            let cls = |c: TimeClass| {
                let h = st.class_hist(c);
                if h.count() == 0 {
                    format!("{} -", c.name())
                } else {
                    format!(
                        "{} p50 {} p99 {} max {}",
                        c.name(),
                        fmt_s(h.quantile(0.5)),
                        fmt_s(h.quantile(0.99)),
                        fmt_s(h.max()),
                    )
                }
            };
            let _ = writeln!(
                out,
                "step {:>3}  measured {}  {} | {} | {} | slowest {} | flagged {:?} | absent {:?}",
                st.step,
                fmt_s(st.measured_s),
                cls(TimeClass::Compute),
                cls(TimeClass::RecvWait),
                cls(TimeClass::Barrier),
                st.slowest_rank.map_or("-".to_string(), |r| r.to_string()),
                st.flagged,
                st.absent,
            );
        }
        for f in &self.flags {
            let _ = writeln!(
                out,
                "  flag step {} rank {}: {} {} > {} (median {}) — {}",
                f.step,
                f.rank,
                f.metric,
                fmt_s(f.value_s),
                fmt_s(f.threshold_s),
                fmt_s(f.median_s),
                f.cause,
            );
        }
        out
    }
}

fn ranks_json(ranks: &[u32]) -> Json {
    Json::Arr(ranks.iter().map(|&r| Json::Num(r as f64)).collect())
}

fn finite_or_null(x: f64) -> Json {
    if x.is_finite() { Json::Num(x) } else { Json::Null }
}

fn fmt_s(s: f64) -> String {
    if s.is_finite() { crate::util::benchkit::fmt_duration(s) } else { "-".to_string() }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn vspan(kind: SpanKind, rank: u32, v0: f64, v1: f64) -> Span {
        Span {
            kind,
            lane: Lane::Cpu,
            rank,
            step: 0,
            depth: 0,
            bytes: 0,
            label: None,
            wall0: f64::NAN,
            wall1: f64::NAN,
            virt0: v0,
            virt1: v1,
        }
    }

    fn fold_uniform_step(t: &mut FleetTelemetry, world: usize, slow: &[(usize, f64)]) {
        for r in 0..world {
            let f = slow.iter().find(|&&(sr, _)| sr == r).map_or(1.0, |&(_, f)| f);
            let c = 1e-3 * f;
            t.fold(&vspan(SpanKind::Compute, r as u32, 0.0, c));
            t.fold(&vspan(SpanKind::Exchange, r as u32, c, c + 2e-3));
            t.fold(&vspan(SpanKind::Barrier, r as u32, c + 2e-3, 5e-3));
        }
    }

    #[test]
    fn detector_flags_injected_stragglers_only() {
        let mut t = FleetTelemetry::new(16);
        fold_uniform_step(&mut t, 16, &[(3, 8.0)]);
        let sc = Scenario { stragglers: vec![(3, 8.0)], seed: 1, ..Scenario::default() };
        t.end_step(0, 5e-3, (0.0, 5e-3), Some(&sc));
        let st = &t.steps()[0];
        assert_eq!(st.flagged, vec![3]);
        assert!(st.absent.is_empty());
        assert_eq!(st.slowest_rank, Some(3));
        let flag = t.flags().iter().find(|f| f.metric == "compute_s").unwrap();
        assert_eq!(flag.rank, 3);
        assert!(flag.expected, "scenario cross-check must confirm the straggler");
        assert!(flag.cause.contains("straggler"), "{}", flag.cause);
        // uniform step: nothing flagged
        let mut u = FleetTelemetry::new(16);
        fold_uniform_step(&mut u, 16, &[]);
        u.end_step(0, 5e-3, (0.0, 5e-3), Some(&Scenario::none(1)));
        assert!(u.steps()[0].flagged.is_empty());
        assert!(u.flags().is_empty());
    }

    #[test]
    fn absent_ranks_detected_and_crash_attributed() {
        let mut t = FleetTelemetry::new(8);
        for r in 0..8u32 {
            if r == 2 {
                continue; // rank 2 reports nothing this step
            }
            t.fold(&vspan(SpanKind::Compute, r, 0.0, 1e-3));
        }
        let sc = Scenario { crashes: vec![(2, 0, 3)], seed: 1, ..Scenario::default() };
        t.end_step(0, 1e-3, (0.0, 1e-3), Some(&sc));
        assert_eq!(t.steps()[0].absent, vec![2]);
        let flag = t.flags().iter().find(|f| f.metric == "absent").unwrap();
        assert_eq!(flag.rank, 2);
        assert!(flag.expected);
        assert!(flag.cause.contains("crash"), "{}", flag.cause);
    }

    #[test]
    fn exemplars_stay_bounded_and_track_anomalies() {
        let mut t = FleetTelemetry::with_exemplars(64, 3);
        assert!(t.is_exemplar(0), "rank 0 is always an exemplar");
        assert!(!t.is_exemplar(7));
        // fold returns the retain decision
        assert!(t.fold(&vspan(SpanKind::Compute, 0, 0.0, 1.0)));
        assert!(!t.fold(&vspan(SpanKind::Compute, 7, 0.0, 1.0)));
        // a straggler gets flagged and becomes an exemplar for later steps
        fold_uniform_step(&mut t, 64, &[(7, 8.0)]);
        t.end_step(0, 5e-3, (0.0, 5e-3), None);
        assert!(t.is_exemplar(7));
        // the budget caps the set no matter how many anomalies show up
        for step in 1..20 {
            fold_uniform_step(&mut t, 64, &[(step as usize + 8, 8.0)]);
            t.end_step(step, 5e-3, (0.0, 5e-3), None);
        }
        assert!(t.exemplar_ranks().len() <= 3);
    }

    #[test]
    fn send_spans_count_bytes_per_link_class() {
        let mut t = FleetTelemetry::new(4);
        let mut s = vspan(SpanKind::Send, 1, 0.0, 1e-3);
        s.lane = Lane::EgressIntra;
        s.bytes = 100;
        t.fold(&s);
        s.lane = Lane::EgressInter;
        s.bytes = 7;
        t.fold(&s);
        t.end_step(0, 1e-3, (0.0, 1e-3), None);
        assert_eq!(t.steps()[0].intra_bytes, 100);
        assert_eq!(t.steps()[0].inter_bytes, 7);
    }

    #[test]
    fn report_roundtrips_through_json_parser() {
        let mut t = FleetTelemetry::new(8);
        fold_uniform_step(&mut t, 8, &[(5, 4.0)]);
        let sc = Scenario { stragglers: vec![(5, 4.0)], seed: 1, ..Scenario::default() };
        t.end_step(0, 5e-3, (0.0, 5e-3), Some(&sc));
        let mut meta = BTreeMap::new();
        meta.insert("fabric".to_string(), Json::Str("fleet".to_string()));
        let report = t.report("unit", meta);
        assert_eq!(report.flagged_ranks, vec![5]);
        let j = report.to_json();
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.get("schema_version").unwrap().as_usize(), Some(1));
        assert_eq!(parsed.get("ranks").unwrap().as_usize(), Some(8));
        assert_eq!(parsed.get("fabric").unwrap().as_str(), Some("fleet"));
        let flagged = parsed.get("flagged_ranks").unwrap().as_arr().unwrap();
        assert_eq!(flagged.len(), 1);
        assert_eq!(flagged[0].as_usize(), Some(5));
        let steps = parsed.get("steps").unwrap().as_arr().unwrap();
        assert_eq!(steps.len(), 1);
        let classes = steps[0].get("classes").unwrap();
        assert!(classes.get("compute").unwrap().get("p99").unwrap().as_f64().is_some());
        let text = report.summary();
        assert!(text.contains("flagged ranks [5]"), "{text}");
        assert!(text.contains("straggler"), "{text}");
    }
}
