//! Span model: what one traced interval looks like.
//!
//! A [`Span`] is a typed interval on one rank's timeline, stamped on **two
//! clocks**: the wall clock (seconds since the tracer epoch, from
//! `Instant`) and the vfabric virtual clock (seconds of modelled time).
//! Either stamp may be absent (`NaN` internally, `null` in JSON): spans
//! recorded on the coordinator thread have no virtual coordinate, and
//! port-occupancy spans booked into the virtual future have no meaningful
//! wall extent.
//!
//! Spans on one rank are split across [`Lane`]s so that each lane is a
//! properly nested tree: the cpu lane carries the rank's execution
//! (compute, encode, waits), while the egress/ingress lanes carry the
//! fabric port busy intervals, which overlap the cpu timeline by design
//! (sends are non-blocking). [`check_nesting`] verifies the tree property
//! per `(rank, lane, clock)`.

use crate::util::json::Json;
use std::collections::BTreeMap;

/// What kind of work a span covers. `step_level` kinds are recorded at
/// `--trace step` and above; the rest only at `--trace full`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SpanKind {
    /// Replayed forward/backward compute (virtual `elapse`) or the
    /// coordinator-side model step (wall).
    Compute,
    /// One rank's whole collective exchange for a step.
    Exchange,
    /// End-of-step synchronisation gap: the rank finished early and waits
    /// for the slowest rank.
    Barrier,
    /// Gradient residual + top-k selection on the coordinator.
    Sparsify,
    /// One gradient bucket's allreduce inside an exchange.
    Bucket,
    /// One schedule round / phase (recursive-doubling stride, ring slot,
    /// hierarchical hop) — labelled.
    Round,
    /// Codec-chain container encode (pipeline side).
    Encode,
    /// Wire segment pack (schedule side, via `SegmentCodec`).
    Pack,
    /// Wire segment decode.
    Decode,
    /// Sparse merge of a decoded peer contribution.
    Merge,
    /// Egress port occupancy for one message.
    Send,
    /// Ingress port occupancy for one message.
    Recv,
    /// Receiver blocked waiting for a message to be delivered.
    RecvWait,
}

impl SpanKind {
    pub fn name(self) -> &'static str {
        match self {
            SpanKind::Compute => "compute",
            SpanKind::Exchange => "exchange",
            SpanKind::Barrier => "barrier",
            SpanKind::Sparsify => "sparsify",
            SpanKind::Bucket => "bucket",
            SpanKind::Round => "round",
            SpanKind::Encode => "encode",
            SpanKind::Pack => "pack",
            SpanKind::Decode => "decode",
            SpanKind::Merge => "merge",
            SpanKind::Send => "send",
            SpanKind::Recv => "recv",
            SpanKind::RecvWait => "recv_wait",
        }
    }

    /// Recorded at `--trace step` (coarse step anatomy); everything else
    /// needs `--trace full`.
    pub fn step_level(self) -> bool {
        matches!(self, SpanKind::Compute | SpanKind::Exchange | SpanKind::Barrier)
    }
}

/// Which timeline of a rank a span lives on. Chrome-trace export maps the
/// rank to a process and the lane to a thread, so overlapping port
/// bookings never collide with the cpu tree.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Lane {
    /// The rank's execution timeline (a nested span tree).
    Cpu,
    /// The rank's overlapped encoder thread (double-buffered pipeline):
    /// runs concurrently with [`Lane::Cpu`] by design.
    Encoder,
    /// Intra-node egress port occupancy.
    EgressIntra,
    /// Inter-node egress port occupancy.
    EgressInter,
    /// Intra-node ingress port occupancy.
    IngressIntra,
    /// Inter-node ingress port occupancy.
    IngressInter,
}

impl Lane {
    pub fn name(self) -> &'static str {
        match self {
            Lane::Cpu => "cpu",
            Lane::Encoder => "encoder",
            Lane::EgressIntra => "egress.intra",
            Lane::EgressInter => "egress.inter",
            Lane::IngressIntra => "ingress.intra",
            Lane::IngressInter => "ingress.inter",
        }
    }

    /// Stable thread id for Chrome-trace export (0 sorts first).
    pub fn tid(self) -> u32 {
        match self {
            Lane::Cpu => 0,
            Lane::Encoder => 1,
            Lane::EgressIntra => 2,
            Lane::EgressInter => 3,
            Lane::IngressIntra => 4,
            Lane::IngressInter => 5,
        }
    }

    /// Egress lane for a vfabric link class (0 = intra, 1 = inter).
    pub fn egress(class: usize) -> Lane {
        if class == 0 { Lane::EgressIntra } else { Lane::EgressInter }
    }

    /// Ingress lane for a vfabric link class.
    pub fn ingress(class: usize) -> Lane {
        if class == 0 { Lane::IngressIntra } else { Lane::IngressInter }
    }
}

/// One traced interval. Times are `f64` seconds; `NaN` means "no stamp on
/// this clock" and serialises as `null`.
#[derive(Clone, Debug)]
pub struct Span {
    pub kind: SpanKind,
    pub lane: Lane,
    pub rank: u32,
    /// Training step the span belongs to (stamped when the tracer drains).
    pub step: u32,
    /// Nesting depth within the lane (0 = top level).
    pub depth: u16,
    /// Payload bytes attributed to the span (0 when not applicable).
    pub bytes: u64,
    /// Free-form qualifier ("bucket 1", "stride 2", "hop intra_reduce").
    pub label: Option<Box<str>>,
    /// Wall clock, seconds since tracer epoch.
    pub wall0: f64,
    pub wall1: f64,
    /// Virtual clock, seconds of modelled fabric time.
    pub virt0: f64,
    pub virt1: f64,
}

impl Span {
    pub fn has_wall(&self) -> bool {
        self.wall0.is_finite() && self.wall1.is_finite()
    }

    pub fn has_virtual(&self) -> bool {
        self.virt0.is_finite() && self.virt1.is_finite()
    }

    pub fn wall_dur(&self) -> f64 {
        self.wall1 - self.wall0
    }

    pub fn virt_dur(&self) -> f64 {
        self.virt1 - self.virt0
    }

    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("kind".to_string(), Json::Str(self.kind.name().to_string()));
        m.insert("lane".to_string(), Json::Str(self.lane.name().to_string()));
        m.insert("rank".to_string(), Json::Num(self.rank as f64));
        m.insert("step".to_string(), Json::Num(self.step as f64));
        m.insert("depth".to_string(), Json::Num(self.depth as f64));
        if self.bytes > 0 {
            m.insert("bytes".to_string(), Json::Num(self.bytes as f64));
        }
        if let Some(l) = &self.label {
            m.insert("label".to_string(), Json::Str(l.to_string()));
        }
        m.insert("wall0".to_string(), numf(self.wall0));
        m.insert("wall1".to_string(), numf(self.wall1));
        m.insert("virt0".to_string(), numf(self.virt0));
        m.insert("virt1".to_string(), numf(self.virt1));
        Json::Obj(m)
    }
}

fn numf(x: f64) -> Json {
    if x.is_finite() { Json::Num(x) } else { Json::Null }
}

/// Verify that spans form proper trees per `(rank, lane)`: siblings on one
/// lane never partially overlap — any two spans are either disjoint or one
/// contains the other. Checked independently on each clock a span carries.
/// Returns the first violation as an error string.
pub fn check_nesting(spans: &[Span]) -> Result<(), String> {
    // (rank, lane, clock) -> intervals
    let mut groups: BTreeMap<(u32, u32, u8), Vec<(f64, f64, SpanKind)>> = BTreeMap::new();
    for s in spans {
        if s.has_wall() {
            groups.entry((s.rank, s.lane.tid(), 0)).or_default().push((
                s.wall0, s.wall1, s.kind,
            ));
        }
        if s.has_virtual() {
            groups.entry((s.rank, s.lane.tid(), 1)).or_default().push((
                s.virt0, s.virt1, s.kind,
            ));
        }
    }
    const EPS: f64 = 1e-12;
    for ((rank, tid, clock), mut iv) in groups {
        // sort by start asc, end desc: a containing span precedes its children
        iv.sort_by(|a, b| {
            a.0.partial_cmp(&b.0).unwrap().then(b.1.partial_cmp(&a.1).unwrap())
        });
        let mut stack: Vec<(f64, f64)> = Vec::new();
        for (t0, t1, kind) in iv {
            if t1 < t0 - EPS {
                return Err(format!(
                    "negative span {} on rank {rank} lane {tid}: [{t0}, {t1}]",
                    kind.name()
                ));
            }
            while let Some(&(_, top1)) = stack.last() {
                if top1 <= t0 + EPS {
                    stack.pop();
                } else {
                    break;
                }
            }
            if let Some(&(top0, top1)) = stack.last() {
                if t1 > top1 + EPS {
                    let clk = if clock == 0 { "wall" } else { "virtual" };
                    return Err(format!(
                        "overlapping siblings on rank {rank} lane {tid} ({clk} clock): \
                         {} [{t0}, {t1}] straddles enclosing [{top0}, {top1}]",
                        kind.name()
                    ));
                }
            }
            stack.push((t0, t1));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sp(rank: u32, w0: f64, w1: f64) -> Span {
        Span {
            kind: SpanKind::Compute,
            lane: Lane::Cpu,
            rank,
            step: 0,
            depth: 0,
            bytes: 0,
            label: None,
            wall0: w0,
            wall1: w1,
            virt0: f64::NAN,
            virt1: f64::NAN,
        }
    }

    #[test]
    fn nesting_accepts_trees() {
        // parent [0,10] with children [1,4], [4,9]; sibling [10,12]
        let spans =
            vec![sp(0, 0.0, 10.0), sp(0, 1.0, 4.0), sp(0, 4.0, 9.0), sp(0, 10.0, 12.0)];
        assert!(check_nesting(&spans).is_ok());
    }

    #[test]
    fn nesting_rejects_partial_overlap() {
        let spans = vec![sp(0, 0.0, 5.0), sp(0, 3.0, 8.0)];
        let err = check_nesting(&spans).unwrap_err();
        assert!(err.contains("overlapping"), "{err}");
    }

    #[test]
    fn nesting_is_per_rank_and_lane() {
        // identical overlapping intervals on different ranks: fine
        let spans = vec![sp(0, 0.0, 5.0), sp(1, 3.0, 8.0)];
        assert!(check_nesting(&spans).is_ok());
        // and on different lanes of one rank: fine
        let mut a = sp(0, 0.0, 5.0);
        let mut b = sp(0, 3.0, 8.0);
        a.lane = Lane::Cpu;
        b.lane = Lane::EgressIntra;
        assert!(check_nesting(&[a, b]).is_ok());
    }

    #[test]
    fn span_json_nulls_missing_clock() {
        let s = sp(2, 0.5, 1.5);
        let j = s.to_json();
        assert_eq!(j.get("wall0").unwrap().as_f64(), Some(0.5));
        assert_eq!(j.get("virt0"), Some(&Json::Null));
        assert_eq!(j.get("kind").unwrap().as_str(), Some("compute"));
    }
}
