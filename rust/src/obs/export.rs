//! Trace exporters: Chrome `trace_event` JSON, the `TRACE_<name>.json`
//! artifact, and the terminal critical-path summary.
//!
//! One artifact serves every consumer: `TRACE_<name>.json` is a JSON
//! object whose `traceEvents` array is valid Chrome trace format (drop the
//! file into Perfetto / `chrome://tracing` and each rank renders as a
//! process with one thread per lane), while the sibling `spans`, `steps`,
//! and `registry` fields carry the full dual-clock data for scripted
//! analysis. The timeline clock is the vfabric virtual clock when any
//! span carries one (virtual-fabric runs), else the wall clock.

use super::span::{Lane, Span, SpanKind};
use super::TraceLevel;
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::path::PathBuf;

/// Schema version for `TRACE_*.json` artifacts (see also
/// [`crate::util::benchkit::SCHEMA_VERSION`] for `BENCH_*.json`).
pub const TRACE_SCHEMA_VERSION: u32 = 1;

/// Per-step timing envelope recorded by the trainer: the numbers the span
/// attribution must reconcile with.
#[derive(Clone, Debug)]
pub struct StepWindow {
    pub step: u32,
    /// `measured_step_s` for this step (virtual seconds on the virtual
    /// fabric, wall seconds on the instant fabric).
    pub measured_s: f64,
    /// Mean per-rank idle (NaN when the fabric doesn't measure idleness).
    pub idle_mean_s: f64,
    /// Virtual-clock extent of the step (NaN on the instant fabric).
    pub virt0: f64,
    pub virt1: f64,
}

/// A drained, exportable trace for one run.
pub struct TraceReport {
    /// Artifact stem: written as `TRACE_<name>.json`.
    pub name: String,
    pub level: TraceLevel,
    pub ranks: usize,
    /// Free-form run metadata (schedule, model, fabric, scenario).
    pub meta: BTreeMap<String, Json>,
    pub steps: Vec<StepWindow>,
    pub spans: Vec<Span>,
    /// Snapshot of the run's [`super::MetricsRegistry`].
    pub registry: Json,
}

/// Virtual seconds of clock-advancing activity on one rank's cpu lane.
///
/// The decomposition is chosen from the lanes the run actually
/// instruments: when the rank has cpu-lane `Exchange` spans (the threaded
/// workers wrap the whole exchange section in one, and the fleet runner
/// synthesises one per rank), the partition is compute + exchange +
/// barrier — recv waits *nest inside* the exchange window, so counting
/// both would double-attribute and the coverage column would over-report.
/// Only when no exchange span exists (step-anatomy traces built from raw
/// wait spans) does the sum fall back to compute + recv_wait + barrier.
/// Either way the chosen kinds tile the rank's virtual timeline, so the
/// sum reconciles with `measured_step_s`.
pub fn attributed_s(spans: &[Span], rank: u32) -> f64 {
    let on_cpu = |s: &&Span| s.rank == rank && s.lane == Lane::Cpu && s.has_virtual();
    let has_exchange = spans.iter().filter(on_cpu).any(|s| s.kind == SpanKind::Exchange);
    let mid = if has_exchange { SpanKind::Exchange } else { SpanKind::RecvWait };
    spans
        .iter()
        .filter(on_cpu)
        .filter(|s| matches!(s.kind, SpanKind::Compute | SpanKind::Barrier) || s.kind == mid)
        .map(|s| s.virt_dur())
        .sum()
}

impl TraceReport {
    /// Prefix the artifact stem with a job identifier, so the export
    /// lands at `TRACE_<job>_<name>.json` — two tenants of the shared
    /// reduction service tracing the same run name never clobber each
    /// other. The job also lands in the payload's metadata.
    pub fn for_job(mut self, job: &str) -> Self {
        self.name = format!("{job}_{}", self.name);
        self.meta.insert("job".to_string(), Json::Str(job.to_string()));
        self
    }

    /// True when the report carries virtual-clock data (virtual fabric).
    pub fn has_virtual(&self) -> bool {
        self.spans.iter().any(|s| s.has_virtual())
    }

    /// Chrome `trace_event` JSON: `{"traceEvents": [...]}`. Ranks map to
    /// processes, lanes to threads; `ts`/`dur` are microseconds on the
    /// report's timeline clock. Spans lacking that clock are omitted from
    /// the timeline (they remain in [`TraceReport::spans`]).
    pub fn chrome_trace(&self) -> Json {
        let virt = self.has_virtual();
        let mut events = Vec::new();
        let mut lanes_seen: BTreeMap<(u32, u32), &'static str> = BTreeMap::new();
        for s in &self.spans {
            let (t0, t1) = if virt {
                if !s.has_virtual() {
                    continue;
                }
                (s.virt0, s.virt1)
            } else {
                if !s.has_wall() {
                    continue;
                }
                (s.wall0, s.wall1)
            };
            lanes_seen.insert((s.rank, s.lane.tid()), s.lane.name());
            let mut ev = BTreeMap::new();
            let name = match &s.label {
                Some(l) => format!("{} {}", s.kind.name(), l),
                None => s.kind.name().to_string(),
            };
            ev.insert("name".to_string(), Json::Str(name));
            ev.insert("cat".to_string(), Json::Str(s.kind.name().to_string()));
            ev.insert("ph".to_string(), Json::Str("X".to_string()));
            ev.insert("pid".to_string(), Json::Num(s.rank as f64));
            ev.insert("tid".to_string(), Json::Num(s.lane.tid() as f64));
            ev.insert("ts".to_string(), Json::Num(t0 * 1e6));
            ev.insert("dur".to_string(), Json::Num((t1 - t0).max(0.0) * 1e6));
            let mut args = BTreeMap::new();
            args.insert("step".to_string(), Json::Num(s.step as f64));
            if s.bytes > 0 {
                args.insert("bytes".to_string(), Json::Num(s.bytes as f64));
            }
            ev.insert("args".to_string(), Json::Obj(args));
            events.push(Json::Obj(ev));
        }
        // metadata events: name each rank's process and each lane's thread
        for rank in 0..self.ranks as u32 {
            events.push(meta_event("process_name", rank, None, &format!("rank {rank}")));
        }
        for ((rank, tid), name) in lanes_seen {
            events.push(meta_event("thread_name", rank, Some(tid), name));
        }
        let mut top = BTreeMap::new();
        top.insert("traceEvents".to_string(), Json::Arr(events));
        top.insert("displayTimeUnit".to_string(), Json::Str("ms".to_string()));
        Json::Obj(top)
    }

    /// The full `TRACE_<name>.json` payload: Chrome `traceEvents` plus the
    /// dual-clock span list, per-step windows, and the metrics snapshot.
    pub fn to_json(&self) -> Json {
        let Json::Obj(mut top) = self.chrome_trace() else { unreachable!() };
        top.insert("schema_version".to_string(), Json::Num(TRACE_SCHEMA_VERSION as f64));
        top.insert("name".to_string(), Json::Str(self.name.clone()));
        top.insert("level".to_string(), Json::Str(self.level.name().to_string()));
        top.insert("ranks".to_string(), Json::Num(self.ranks as f64));
        top.insert("clock".to_string(), Json::Str(
            if self.has_virtual() { "virtual" } else { "wall" }.to_string(),
        ));
        for (k, v) in &self.meta {
            top.insert(k.clone(), v.clone());
        }
        let steps = self
            .steps
            .iter()
            .map(|w| {
                let mut m = BTreeMap::new();
                m.insert("step".to_string(), Json::Num(w.step as f64));
                m.insert("measured_s".to_string(), Json::Num(w.measured_s));
                m.insert("idle_mean_s".to_string(), finite_or_null(w.idle_mean_s));
                m.insert("virt0".to_string(), finite_or_null(w.virt0));
                m.insert("virt1".to_string(), finite_or_null(w.virt1));
                Json::Obj(m)
            })
            .collect();
        top.insert("steps".to_string(), Json::Arr(steps));
        top.insert("spans".to_string(), Json::Arr(self.spans.iter().map(Span::to_json).collect()));
        top.insert("registry".to_string(), self.registry.clone());
        Json::Obj(top)
    }

    /// Write `TRACE_<name>.json` at the repo root (next to the
    /// `BENCH_*.json` trajectory artifacts) and return the path.
    pub fn write(&self) -> std::io::Result<PathBuf> {
        let root = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            .parent()
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("."));
        let path = root.join(format!("TRACE_{}.json", self.name));
        std::fs::write(&path, self.to_json().to_string())?;
        Ok(path)
    }

    /// Critical-path fraction of `measured_s` explained by the traced
    /// decomposition of the slowest rank in `step`. `None` without
    /// virtual-clock data or a matching step window.
    pub fn reconciliation(&self, step: u32) -> Option<f64> {
        let w = self.steps.iter().find(|w| w.step == step)?;
        if !w.measured_s.is_finite() || w.measured_s <= 0.0 {
            return None;
        }
        let (_, att) = self.slowest_rank(step)?;
        Some(att / w.measured_s)
    }

    /// The rank with the largest attributed virtual time in `step` — the
    /// critical-path rank — and its attribution.
    fn slowest_rank(&self, step: u32) -> Option<(u32, f64)> {
        let in_step: Vec<Span> =
            self.spans.iter().filter(|s| s.step == step).cloned().collect();
        if !in_step.iter().any(|s| s.has_virtual()) {
            return None;
        }
        // the critical-path rank is the one that is least idle: largest
        // compute + exchange (or compute + recv_wait when the rank has no
        // exchange span — same instrumentation-aware rule as
        // [`attributed_s`]); barrier excluded — the slowest rank's barrier
        // is ~0 while early finishers park in theirs
        let busy = |rank: u32| -> f64 {
            let on_cpu =
                |s: &&Span| s.rank == rank && s.lane == Lane::Cpu && s.has_virtual();
            let has_exchange = in_step.iter().filter(on_cpu).any(|s| s.kind == SpanKind::Exchange);
            let mid = if has_exchange { SpanKind::Exchange } else { SpanKind::RecvWait };
            in_step
                .iter()
                .filter(on_cpu)
                .filter(|s| s.kind == SpanKind::Compute || s.kind == mid)
                .map(|s| s.virt_dur())
                .sum()
        };
        let slowest =
            (0..self.ranks as u32).max_by(|a, b| busy(*a).partial_cmp(&busy(*b)).unwrap())?;
        Some((slowest, attributed_s(&in_step, slowest)))
    }

    /// Terminal per-step critical-path breakdown (`--trace-summary`).
    pub fn summary(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(
            out,
            "trace '{}': {} rank(s), level {}, {} span(s), clock {}",
            self.name,
            self.ranks,
            self.level.name(),
            self.spans.len(),
            if self.has_virtual() { "virtual" } else { "wall" },
        );
        for w in &self.steps {
            let in_step: Vec<&Span> =
                self.spans.iter().filter(|s| s.step == w.step).collect();
            match self.slowest_rank(w.step) {
                Some((rank, att)) => {
                    let sum_kind = |k: SpanKind| -> f64 {
                        in_step
                            .iter()
                            .filter(|s| s.rank == rank && s.has_virtual() && s.kind == k)
                            .map(|s| s.virt_dur())
                            .sum()
                    };
                    let compute = sum_kind(SpanKind::Compute);
                    let wait = sum_kind(SpanKind::RecvWait);
                    let barrier = sum_kind(SpanKind::Barrier);
                    let exchange = sum_kind(SpanKind::Exchange);
                    // same instrumentation-aware middle column as
                    // [`attributed_s`]: exchange when the rank records one
                    // (waits nest inside it), recv_wait otherwise
                    let (mid_name, mid) =
                        if exchange > 0.0 { ("exchange", exchange) } else { ("recv_wait", wait) };
                    let cov = if w.measured_s > 0.0 { att / w.measured_s } else { f64::NAN };
                    let pct = |x: f64| {
                        if w.measured_s > 0.0 { 100.0 * x / w.measured_s } else { f64::NAN }
                    };
                    let _ = writeln!(
                        out,
                        "step {:>3}  measured {}  slowest rank {}: compute {} ({:.1}%) | \
                         {} {} ({:.1}%) | barrier {} | coverage {:.1}%",
                        w.step,
                        fmt_s(w.measured_s),
                        rank,
                        fmt_s(compute),
                        pct(compute),
                        mid_name,
                        fmt_s(mid),
                        pct(mid),
                        fmt_s(barrier),
                        100.0 * cov,
                    );
                    // top detail spans on the critical rank's path
                    let mut detail: Vec<&&Span> = in_step
                        .iter()
                        .filter(|s| {
                            s.rank == rank
                                && s.has_virtual()
                                && matches!(
                                    s.kind,
                                    SpanKind::RecvWait | SpanKind::Round | SpanKind::Bucket
                                )
                        })
                        .collect();
                    detail.sort_by(|a, b| b.virt_dur().partial_cmp(&a.virt_dur()).unwrap());
                    if !detail.is_empty() {
                        let tops: Vec<String> = detail
                            .iter()
                            .take(3)
                            .map(|s| match &s.label {
                                Some(l) => format!("{}[{}] {}", s.kind.name(), l, fmt_s(s.virt_dur())),
                                None => format!("{} {}", s.kind.name(), fmt_s(s.virt_dur())),
                            })
                            .collect();
                        let _ = writeln!(out, "          top: {}", tops.join("; "));
                    }
                }
                None => {
                    // wall-only run: per-kind totals across ranks (worker
                    // threads overlap in wall time, so no coverage claim)
                    let mut by_kind: BTreeMap<&'static str, f64> = BTreeMap::new();
                    for s in &in_step {
                        if s.has_wall() {
                            *by_kind.entry(s.kind.name()).or_default() += s.wall_dur();
                        }
                    }
                    let mut parts: Vec<String> = by_kind
                        .into_iter()
                        .map(|(k, v)| format!("{k} {}", fmt_s(v)))
                        .collect();
                    parts.sort();
                    let _ = writeln!(
                        out,
                        "step {:>3}  measured {} (wall)  totals: {}",
                        w.step,
                        fmt_s(w.measured_s),
                        parts.join(" | "),
                    );
                }
            }
        }
        out
    }
}

/// The canonical JSON string literal for `s`: surrounding quotes
/// included, with `"`, `\`, and every control character escaped. All
/// artifact writers — `BENCH_*` ([`crate::util::benchkit`]), `TRACE_*`
/// (this module), `HEALTH_*` ([`super::fleet`]) — serialise through
/// [`Json`], which delegates to the same single escaper this function
/// wraps ([`crate::util::json::write_escaped`]); use this entry point
/// when emitting JSON text outside the [`Json`] tree.
pub fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    crate::util::json::write_escaped(&mut out, s);
    out
}

fn meta_event(name: &str, pid: u32, tid: Option<u32>, value: &str) -> Json {
    let mut ev = BTreeMap::new();
    ev.insert("name".to_string(), Json::Str(name.to_string()));
    ev.insert("ph".to_string(), Json::Str("M".to_string()));
    ev.insert("pid".to_string(), Json::Num(pid as f64));
    if let Some(t) = tid {
        ev.insert("tid".to_string(), Json::Num(t as f64));
    }
    let mut args = BTreeMap::new();
    args.insert("name".to_string(), Json::Str(value.to_string()));
    ev.insert("args".to_string(), Json::Obj(args));
    Json::Obj(ev)
}

fn finite_or_null(x: f64) -> Json {
    if x.is_finite() { Json::Num(x) } else { Json::Null }
}

fn fmt_s(s: f64) -> String {
    crate::util::benchkit::fmt_duration(s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::{Tracer, TraceLevel};

    fn vspan(kind: SpanKind, rank: u32, v0: f64, v1: f64) -> Span {
        Span {
            kind,
            lane: Lane::Cpu,
            rank,
            step: 0,
            depth: 0,
            bytes: 0,
            label: None,
            wall0: f64::NAN,
            wall1: f64::NAN,
            virt0: v0,
            virt1: v1,
        }
    }

    fn report(spans: Vec<Span>, steps: Vec<StepWindow>) -> TraceReport {
        TraceReport {
            name: "unit".to_string(),
            level: TraceLevel::Full,
            ranks: 2,
            meta: BTreeMap::new(),
            steps,
            spans,
            registry: Tracer::new(TraceLevel::Full, 2).registry().snapshot(),
        }
    }

    #[test]
    fn reconciliation_explains_measured_time() {
        // rank 0: compute 1.0 + wait 3.0 (slowest); rank 1: compute 1.0,
        // barrier 3.0. measured step = 4.0.
        let spans = vec![
            vspan(SpanKind::Compute, 0, 0.0, 1.0),
            vspan(SpanKind::RecvWait, 0, 1.0, 4.0),
            vspan(SpanKind::Compute, 1, 0.0, 1.0),
            vspan(SpanKind::Barrier, 1, 1.0, 4.0),
        ];
        let w = StepWindow { step: 0, measured_s: 4.0, idle_mean_s: 1.5, virt0: 0.0, virt1: 4.0 };
        let r = report(spans, vec![w]);
        let cov = r.reconciliation(0).unwrap();
        assert!((cov - 1.0).abs() < 1e-9, "coverage {cov}");
        let text = r.summary();
        assert!(text.contains("slowest rank 0"), "{text}");
        assert!(text.contains("coverage 100.0%"), "{text}");
    }

    #[test]
    fn exchange_spans_replace_waits_in_coverage_not_double_count() {
        // fleet-style trace: synthesized Compute/Exchange/Barrier tile the
        // step, with the runner's RecvWait spans nested INSIDE the
        // exchange window. Coverage must be exactly 100%, not 100% + the
        // nested waits.
        let spans = vec![
            vspan(SpanKind::Compute, 0, 0.0, 1.0),
            vspan(SpanKind::Exchange, 0, 1.0, 4.0),
            vspan(SpanKind::RecvWait, 0, 1.5, 3.5), // nested in the exchange
            vspan(SpanKind::Barrier, 0, 4.0, 4.0),
            vspan(SpanKind::Compute, 1, 0.0, 1.0),
            vspan(SpanKind::Exchange, 1, 1.0, 2.0),
            vspan(SpanKind::Barrier, 1, 2.0, 4.0),
        ];
        let w = StepWindow { step: 0, measured_s: 4.0, idle_mean_s: 1.0, virt0: 0.0, virt1: 4.0 };
        let r = report(spans, vec![w]);
        let cov = r.reconciliation(0).unwrap();
        assert!((cov - 1.0).abs() < 1e-9, "coverage {cov} (waits double-counted?)");
        let text = r.summary();
        assert!(text.contains("slowest rank 0"), "{text}");
        assert!(text.contains("exchange"), "{text}");
        assert!(text.contains("coverage 100.0%"), "{text}");
    }

    #[test]
    fn json_escape_roundtrips_hostile_strings() {
        // every control character, plus quote/backslash/unicode mixtures —
        // parse(escape(s)) must give back exactly s
        let mut corpus: Vec<String> = (0u32..0x20).map(|c| {
            format!("a{}b", char::from_u32(c).unwrap())
        }).collect();
        corpus.extend(
            [
                "",
                "plain",
                "quote\"inside",
                "back\\slash",
                "\\\"both\\\"",
                "tab\there\nnewline\rcr",
                "trailing backslash\\",
                "\"",
                "\\",
                "unicode: π ≈ 3, ランク, 🚀",
                "\u{1b}[31mansi\u{1b}[0m",
                "nul\u{0}embedded",
            ]
            .map(String::from),
        );
        // pseudo-random mixtures of the hostile alphabet
        let alphabet = ['"', '\\', '\n', '\r', '\t', '\u{1}', '\u{1f}', 'x', 'é'];
        let mut state = 0x9e3779b97f4a7c15u64;
        for len in 0..64 {
            let mut s = String::new();
            for _ in 0..len {
                state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                s.push(alphabet[(state >> 33) as usize % alphabet.len()]);
            }
            corpus.push(s);
        }
        for s in &corpus {
            let lit = json_escape(s);
            let parsed = Json::parse(&lit)
                .unwrap_or_else(|e| panic!("escape of {s:?} produced unparseable {lit:?}: {e:?}"));
            assert_eq!(parsed.as_str(), Some(s.as_str()), "round-trip of {s:?} via {lit:?}");
            // and embedded in an object, as the artifact writers emit it
            let obj = format!("{{{}:{}}}", json_escape("k"), lit);
            assert_eq!(Json::parse(&obj).unwrap().get("k").unwrap().as_str(), Some(s.as_str()));
        }
    }

    #[test]
    fn chrome_trace_roundtrips_and_separates_lanes() {
        let mut port = vspan(SpanKind::Send, 0, 0.5, 1.5);
        port.lane = Lane::EgressIntra;
        port.bytes = 4096;
        port.wall0 = 0.01;
        port.wall1 = 0.01;
        let spans = vec![vspan(SpanKind::Compute, 0, 0.0, 1.0), port];
        let r = report(spans, vec![]);
        let j = r.to_json();
        // round-trips through the repo's own JSON parser
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.get("schema_version").unwrap().as_usize(), Some(1));
        assert_eq!(parsed.get("clock").unwrap().as_str(), Some("virtual"));
        let events = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        let xs: Vec<&Json> = events
            .iter()
            .filter(|e| e.get("ph").unwrap().as_str() == Some("X"))
            .collect();
        assert_eq!(xs.len(), 2);
        let send = xs.iter().find(|e| e.get("cat").unwrap().as_str() == Some("send")).unwrap();
        assert_eq!(send.get("tid").unwrap().as_usize(), Some(Lane::EgressIntra.tid() as usize));
        assert_eq!(send.get("ts").unwrap().as_f64(), Some(0.5e6));
        assert_eq!(send.get("dur").unwrap().as_f64(), Some(1e6));
        // process/thread metadata present for Perfetto
        assert!(events.iter().any(|e| e.get("name").unwrap().as_str() == Some("process_name")));
        assert!(events.iter().any(|e| e.get("name").unwrap().as_str() == Some("thread_name")));
    }

    #[test]
    fn wall_only_report_uses_wall_clock() {
        let mut s = vspan(SpanKind::Compute, 0, f64::NAN, f64::NAN);
        s.wall0 = 0.0;
        s.wall1 = 0.25;
        let w = StepWindow {
            step: 0,
            measured_s: 0.25,
            idle_mean_s: f64::NAN,
            virt0: f64::NAN,
            virt1: f64::NAN,
        };
        let r = report(vec![s], vec![w]);
        assert!(!r.has_virtual());
        assert!(r.reconciliation(0).is_none());
        let j = r.to_json();
        assert_eq!(j.get("clock").unwrap().as_str(), Some("wall"));
        let text = r.summary();
        assert!(text.contains("(wall)"), "{text}");
    }
}
