//! Experiment helpers shared by the paper-figure benches: canned
//! training-run constructors and small formatting utilities. Keeps each
//! `rust/benches/figN.rs` focused on its figure.

use crate::coordinator::{CompressionSpec, ModelKind, TrainConfig, TrainReport, Trainer};

/// Default scaled-down experiment sizes (documented in EXPERIMENTS.md):
/// the paper trains 328 epochs on 8 V100 nodes; we run `steps` synchronous
/// steps on in-process workers — enough for orderings/crossovers to show.
pub const FIG_STEPS: usize = 50;
pub const FIG_WORKERS: usize = 2;

/// Run one training configuration and return its report.
pub fn run(
    model: ModelKind,
    artifact: &str,
    steps: usize,
    workers: usize,
    compression: Option<CompressionSpec>,
) -> anyhow::Result<TrainReport> {
    let mut cfg = TrainConfig::new(model, artifact);
    cfg.steps = steps;
    cfg.workers = workers;
    cfg.compression = compression;
    Trainer::new(cfg)?.run()
}

/// Run with a dense 3LC path (Fig 9 baseline).
pub fn run_3lc(
    model: ModelKind,
    artifact: &str,
    steps: usize,
    workers: usize,
    s: f32,
) -> anyhow::Result<TrainReport> {
    let mut cfg = TrainConfig::new(model, artifact);
    cfg.steps = steps;
    cfg.workers = workers;
    cfg.dense_3lc = Some(s);
    Trainer::new(cfg)?.run()
}

/// `DR_idx^∅` over Top-r — the Fig 6/7 arrangement.
pub fn dr_index(ratio: f64, index: &str, fpr: f64) -> CompressionSpec {
    CompressionSpec::topk(ratio, index, fpr, "raw", f64::NAN)
}

/// `DR_∅^val` over Top-r — the Fig 8 arrangement.
pub fn dr_value(ratio: f64, value: &str, param: f64) -> CompressionSpec {
    CompressionSpec::topk(ratio, "raw", f64::NAN, value, param)
}

/// Typed-spec route over Top-r: full chain/parameter syntax on both
/// sides, with parse errors surfaced instead of panicking —
/// e.g. `dr_spec(0.01, "rle+deflate", "qsgd(bits=6)")`.
pub fn dr_spec(ratio: f64, index: &str, value: &str) -> anyhow::Result<CompressionSpec> {
    Ok(CompressionSpec::with_spec(
        ratio,
        crate::compress::CompressSpec::parse(index, value)?,
    ))
}

/// Percent formatting for relative-volume columns.
pub fn pct(x: f64) -> String {
    format!("{:.2}%", x * 100.0)
}

/// Fail-soft artifact guard for benches.
pub fn need(name: &str) -> bool {
    if crate::runtime::artifact_available(name) {
        true
    } else {
        eprintln!("SKIPPING: artifact '{name}' missing — run `make artifacts` first");
        false
    }
}
