//! Minimal offline stand-in for the `anyhow` crate: a string-backed
//! error type, the `Result` alias, and the `anyhow!` / `bail!` /
//! `ensure!` macros. Matches the subset of the real API this workspace
//! uses (see vendor/README.md).

use std::fmt;

/// A type-erased error carrying a human-readable message.
///
/// Deliberately does NOT implement `std::error::Error` (mirroring real
/// anyhow), which is what makes the blanket `From<E: Error>` impl below
/// coherent with `From<T> for T`.
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from anything displayable.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Self { msg: message.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Self {
        Error::msg(&e)
    }
}

/// `anyhow::Result<T>` — `Result` with [`Error`] as the default error.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)+) => {
        $crate::Error::msg(format!($($arg)+))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return Err($crate::anyhow!($($arg)+))
    };
}

/// Return early with a formatted [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!("condition failed: `{}`", stringify!($cond)));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails(flag: bool) -> Result<u32> {
        ensure!(!flag, "flag was {flag}");
        Ok(7)
    }

    #[test]
    fn macros_and_conversions() {
        assert_eq!(fails(false).unwrap(), 7);
        assert_eq!(fails(true).unwrap_err().to_string(), "flag was true");
        let e = anyhow!("x = {}", 3);
        assert_eq!(format!("{e}"), "x = 3");
        assert_eq!(format!("{e:?}"), "x = 3");
        // `?` conversion from std error types
        let r: Result<i32> = (|| Ok("12".parse::<i32>()?))();
        assert_eq!(r.unwrap(), 12);
        let r: Result<i32> = (|| Ok("nope".parse::<i32>()?))();
        assert!(r.is_err());
    }
}
