//! Offline stub of the xla-rs PJRT binding surface used by
//! `deepreduce::runtime`. The real crate links the PJRT C API; this stub
//! exists so the crate builds without the XLA toolchain. Construction
//! fails at `PjRtClient::cpu()` with a clear message, and everything
//! downstream is unreachable: all artifact-gated tests and benches check
//! `runtime::artifact_available()` before touching the runtime.

use std::fmt;

#[derive(Debug)]
pub struct Error {
    msg: String,
}

impl Error {
    fn new(msg: &str) -> Self {
        Self { msg: msg.to_string() }
    }

    fn unavailable() -> Self {
        Self::new(
            "XLA/PJRT runtime unavailable: this build uses the offline stub \
             (vendor/xla). Install the real xla-rs bindings to execute artifacts.",
        )
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types mirroring the PJRT enum (subset).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S32,
    S64,
    U8,
    U32,
    F16,
    F32,
    F64,
}

/// Marker for element types a [`Literal`] can be built from / read as.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u8 {}

pub struct Literal;

impl Literal {
    pub fn vec1<T: NativeType>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(Error::unavailable())
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error::unavailable())
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable())
    }

    pub fn ty(&self) -> Result<ElementType> {
        Err(Error::unavailable())
    }
}

/// Marker for argument types accepted by [`PjRtLoadedExecutable::execute`].
pub trait BufferArgument {}
impl BufferArgument for Literal {}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable())
    }
}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable())
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable())
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L: BufferArgument>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable())
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::unavailable())
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("offline stub"));
        assert!(Literal::vec1(&[1.0f32]).reshape(&[1]).is_err());
    }
}
