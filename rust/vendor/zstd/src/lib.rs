//! Minimal offline stand-in for `zstd`: the `bulk` compress/decompress
//! API over the shared LZSS codec from the `flate2` shim
//! (see vendor/README.md). Not Zstandard-bitstream compatible.

pub mod bulk {
    use std::io;

    pub fn compress(data: &[u8], _level: i32) -> io::Result<Vec<u8>> {
        Ok(flate2::lz::compress(data))
    }

    /// `capacity` is the caller's upper bound on the decompressed size,
    /// mirroring the real API's preallocation hint.
    pub fn decompress(data: &[u8], capacity: usize) -> io::Result<Vec<u8>> {
        let out = flate2::lz::decompress(data)?;
        if out.len() > capacity {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("decompressed size {} exceeds capacity {capacity}", out.len()),
            ));
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn bulk_roundtrip() {
        let data = vec![3u8; 10_000];
        let c = super::bulk::compress(&data, 3).unwrap();
        assert!(c.len() < 100);
        assert_eq!(super::bulk::decompress(&c, data.len()).unwrap(), data);
        assert!(super::bulk::decompress(&c, 10).is_err());
    }
}
