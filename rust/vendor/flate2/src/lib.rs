//! Minimal offline stand-in for `flate2`: the `write::DeflateEncoder` /
//! `read::DeflateDecoder` API over a simple LZSS codec (`lz` module).
//! Lossless and genuinely compressing, but NOT RFC 1951 compatible —
//! only this shim ever decodes the bytes (see vendor/README.md).

use std::io;

/// Compression level (accepted for API compatibility; the LZSS codec has
/// a single operating point).
#[derive(Clone, Copy, Debug)]
pub struct Compression(u32);

impl Compression {
    pub fn new(level: u32) -> Self {
        Self(level)
    }

    pub fn level(&self) -> u32 {
        self.0
    }
}

impl Default for Compression {
    fn default() -> Self {
        Self(6)
    }
}

/// Greedy hash-match LZSS with varint-coded tokens.
///
/// Stream layout: `varint(original_len)` then tokens:
/// - `0x00 varint(n) <n bytes>` — literal run
/// - `0x01 varint(dist) varint(len)` — copy `len` bytes starting `dist`
///   back in the output (dist may be < len: overlapped copy, i.e. RLE)
pub mod lz {
    use std::io;

    const MIN_MATCH: usize = 4;
    const WINDOW: usize = 1 << 16;
    const HASH_BITS: u32 = 15;

    fn write_varint(out: &mut Vec<u8>, mut v: u64) {
        loop {
            let byte = (v & 0x7F) as u8;
            v >>= 7;
            if v == 0 {
                out.push(byte);
                return;
            }
            out.push(byte | 0x80);
        }
    }

    fn read_varint(buf: &[u8], pos: &mut usize) -> io::Result<u64> {
        let mut v = 0u64;
        let mut shift = 0u32;
        loop {
            let b = *buf
                .get(*pos)
                .ok_or_else(|| io::Error::new(io::ErrorKind::InvalidData, "varint truncated"))?;
            *pos += 1;
            v |= ((b & 0x7F) as u64) << shift;
            if b & 0x80 == 0 {
                return Ok(v);
            }
            shift += 7;
            if shift > 63 {
                return Err(io::Error::new(io::ErrorKind::InvalidData, "varint overflow"));
            }
        }
    }

    fn emit_literals(out: &mut Vec<u8>, lits: &[u8]) {
        if !lits.is_empty() {
            out.push(0);
            write_varint(out, lits.len() as u64);
            out.extend_from_slice(lits);
        }
    }

    pub fn compress(data: &[u8]) -> Vec<u8> {
        let mut out = Vec::with_capacity(data.len() / 2 + 16);
        write_varint(&mut out, data.len() as u64);
        let mut head = vec![usize::MAX; 1 << HASH_BITS];
        let hash = |w: u32| -> usize { (w.wrapping_mul(0x9E37_79B1) >> (32 - HASH_BITS)) as usize };
        let mut i = 0usize;
        let mut lit_start = 0usize;
        while i + MIN_MATCH <= data.len() {
            let w = u32::from_le_bytes([data[i], data[i + 1], data[i + 2], data[i + 3]]);
            let h = hash(w);
            let cand = head[h];
            head[h] = i;
            if cand != usize::MAX && i - cand <= WINDOW && data[cand..cand + MIN_MATCH] == data[i..i + MIN_MATCH]
            {
                let mut len = MIN_MATCH;
                while i + len < data.len() && data[cand + len] == data[i + len] {
                    len += 1;
                }
                emit_literals(&mut out, &data[lit_start..i]);
                out.push(1);
                write_varint(&mut out, (i - cand) as u64);
                write_varint(&mut out, len as u64);
                i += len;
                lit_start = i;
            } else {
                i += 1;
            }
        }
        emit_literals(&mut out, &data[lit_start..]);
        out
    }

    pub fn decompress(data: &[u8]) -> io::Result<Vec<u8>> {
        let bad = |m: &str| io::Error::new(io::ErrorKind::InvalidData, m.to_string());
        let mut pos = 0usize;
        let n = read_varint(data, &mut pos)? as usize;
        let mut out = Vec::with_capacity(n);
        while pos < data.len() {
            let tag = data[pos];
            pos += 1;
            match tag {
                0 => {
                    let len = read_varint(data, &mut pos)? as usize;
                    if pos + len > data.len() {
                        return Err(bad("literal run truncated"));
                    }
                    out.extend_from_slice(&data[pos..pos + len]);
                    pos += len;
                }
                1 => {
                    let dist = read_varint(data, &mut pos)? as usize;
                    let len = read_varint(data, &mut pos)? as usize;
                    if dist == 0 || dist > out.len() {
                        return Err(bad("match distance out of range"));
                    }
                    // byte-at-a-time: distances shorter than the length
                    // are overlapped copies (runs)
                    for _ in 0..len {
                        let b = out[out.len() - dist];
                        out.push(b);
                    }
                }
                _ => return Err(bad("unknown token tag")),
            }
        }
        if out.len() != n {
            return Err(bad("decompressed length mismatch"));
        }
        Ok(out)
    }
}

pub mod write {
    use super::{lz, Compression};
    use std::io::{self, Write};

    /// Buffers all input and compresses on `finish()`.
    pub struct DeflateEncoder<W: Write> {
        inner: W,
        buf: Vec<u8>,
    }

    impl<W: Write> DeflateEncoder<W> {
        pub fn new(inner: W, _level: Compression) -> Self {
            Self { inner, buf: Vec::new() }
        }

        pub fn finish(mut self) -> io::Result<W> {
            let compressed = lz::compress(&self.buf);
            self.inner.write_all(&compressed)?;
            Ok(self.inner)
        }
    }

    impl<W: Write> Write for DeflateEncoder<W> {
        fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
            self.buf.extend_from_slice(buf);
            Ok(buf.len())
        }

        fn flush(&mut self) -> io::Result<()> {
            Ok(())
        }
    }
}

pub mod read {
    use super::lz;
    use std::io::{self, Read};

    /// Reads all input on first use, decompresses, then serves bytes.
    pub struct DeflateDecoder<R: Read> {
        inner: Option<R>,
        out: Vec<u8>,
        pos: usize,
    }

    impl<R: Read> DeflateDecoder<R> {
        pub fn new(inner: R) -> Self {
            Self { inner: Some(inner), out: Vec::new(), pos: 0 }
        }

        fn fill(&mut self) -> io::Result<()> {
            if let Some(mut r) = self.inner.take() {
                let mut raw = Vec::new();
                r.read_to_end(&mut raw)?;
                self.out = lz::decompress(&raw)?;
            }
            Ok(())
        }
    }

    impl<R: Read> Read for DeflateDecoder<R> {
        fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
            self.fill()?;
            let n = (self.out.len() - self.pos).min(buf.len());
            buf[..n].copy_from_slice(&self.out[self.pos..self.pos + n]);
            self.pos += n;
            Ok(n)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read, Write};

    #[test]
    fn lz_roundtrip_mixed() {
        let mut data = Vec::new();
        for i in 0..10_000u32 {
            data.extend_from_slice(&(i % 97).to_le_bytes());
        }
        data.extend_from_slice(&[42u8; 5000]);
        let c = lz::compress(&data);
        assert!(c.len() < data.len());
        assert_eq!(lz::decompress(&c).unwrap(), data);
    }

    #[test]
    fn lz_roundtrip_incompressible_and_empty() {
        let data: Vec<u8> = (0..4096u64).map(|i| (i.wrapping_mul(0x9E3779B97F4A7C15) >> 56) as u8).collect();
        assert_eq!(lz::decompress(&lz::compress(&data)).unwrap(), data);
        assert_eq!(lz::decompress(&lz::compress(&[])).unwrap(), Vec::<u8>::new());
        assert_eq!(lz::decompress(&lz::compress(&[7])).unwrap(), vec![7]);
    }

    #[test]
    fn encoder_decoder_api() {
        let data = vec![9u8; 40_000];
        let mut enc = write::DeflateEncoder::new(Vec::new(), Compression::new(6));
        enc.write_all(&data).unwrap();
        let compressed = enc.finish().unwrap();
        assert!(compressed.len() < 100, "run should collapse: {}", compressed.len());
        let mut back = Vec::new();
        read::DeflateDecoder::new(&compressed[..]).read_to_end(&mut back).unwrap();
        assert_eq!(back, data);
    }

    #[test]
    fn decompress_rejects_garbage() {
        assert!(lz::decompress(&[0x05, 0x99, 0x99]).is_err());
    }
}
