//! Minimal offline stand-in for `crc32fast`: standard CRC-32
//! (IEEE 802.3, reflected, polynomial 0xEDB88320) with a const-built
//! byte table. Produces the same digests as the real crate.

const TABLE: [u32; 256] = {
    let mut t = [0u32; 256];
    let mut i = 0usize;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        t[i] = c;
        i += 1;
    }
    t
};

/// Streaming CRC-32 hasher.
#[derive(Clone, Debug)]
pub struct Hasher {
    state: u32,
}

impl Hasher {
    pub fn new() -> Self {
        Self { state: 0xFFFF_FFFF }
    }

    pub fn update(&mut self, data: &[u8]) {
        let mut s = self.state;
        for &b in data {
            s = (s >> 8) ^ TABLE[((s ^ b as u32) & 0xFF) as usize];
        }
        self.state = s;
    }

    pub fn finalize(self) -> u32 {
        self.state ^ 0xFFFF_FFFF
    }
}

impl Default for Hasher {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn crc(data: &[u8]) -> u32 {
        let mut h = Hasher::new();
        h.update(data);
        h.finalize()
    }

    #[test]
    fn known_vectors() {
        // canonical CRC-32 check value
        assert_eq!(crc(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc(b""), 0);
        assert_eq!(crc(b"a"), 0xE8B7_BE43);
    }

    #[test]
    fn streaming_matches_oneshot() {
        let data = b"hello crc32 world";
        let mut h = Hasher::new();
        h.update(&data[..5]);
        h.update(&data[5..]);
        assert_eq!(h.finalize(), crc(data));
    }
}
