//! Property-based roundtrip tests over the full index × value codec
//! matrix (seeded via `util::prng` + `util::testkit`): random densities
//! and shapes, including empty and fully-dense tensors. Locks the
//! growing codec surface down:
//!
//! - lossless × lossless pairs must roundtrip bit-exactly through the
//!   full container wire format;
//! - lossy value codecs must hold their structural contracts (length,
//!   boundedness, finiteness);
//! - Bloom index policies must hold their support contracts (P ⊇ S for
//!   P0, |S̃| ≤ r for P1/P2, true values at reconstructed positions).
//!
//! Runs without artifacts.

use deepreduce::compress::{index_by_name, value_by_name, Container, DeepReduce};
use deepreduce::tensor::SparseTensor;
use deepreduce::util::stats::rel_l2_err;
use deepreduce::util::testkit::{forall, gradient_like, sorted_support};

const LOSSLESS_INDEX: [&str; 6] = ["raw", "bitmap", "rle", "huffman", "delta_varint", "elias"];
const LOSSLESS_VALUE: [&str; 3] = ["raw", "deflate", "zstd"];
const LOSSY_VALUE: [&str; 4] = ["fp16", "qsgd", "fitpoly", "fitdexp"];
const BLOOM_INDEX: [&str; 4] = ["bloom_naive", "bloom_p0", "bloom_p1", "bloom_p2"];
/// chainable byte stages (stage 2 of `head+stage` chains)
const BYTE_STAGES: [&str; 2] = ["deflate", "zstd"];

fn build(index: &str, value: &str, seed: u64) -> DeepReduce {
    DeepReduce::new(
        index_by_name(index, 0.01, seed).unwrap_or_else(|| panic!("index {index}")),
        value_by_name(value, f64::NAN, seed).unwrap_or_else(|| panic!("value {value}")),
    )
}

/// Encode → serialize → parse → decode, through the real wire container.
fn wire_roundtrip(dr: &DeepReduce, sp: &SparseTensor, g: &[f32]) -> anyhow::Result<SparseTensor> {
    let container = dr.encode(sp, Some(g));
    let bytes = container.to_bytes();
    let parsed = Container::from_bytes(&bytes)?;
    dr.decode(&parsed)
}

/// A random (dense gradient, sparse view) pair. Density spans the whole
/// range: roughly 1/6 of cases are empty and 1/6 fully dense.
fn gen_case(rng: &mut deepreduce::util::prng::Rng, size: usize) -> (Vec<f32>, SparseTensor) {
    let d = 1 + rng.below(size as u64) as usize;
    let r = match rng.below(6) {
        0 => 0,
        1 => d,
        _ => rng.below(d as u64 + 1) as usize,
    };
    let g = gradient_like(rng, d);
    let support = sorted_support(rng, d, r);
    (g.clone(), SparseTensor::gather(&g, &support))
}

#[test]
fn lossless_matrix_roundtrips_bit_exactly() {
    forall(
        "codec-matrix-lossless",
        15,
        1200,
        gen_case,
        |(g, sp)| {
            for idx in LOSSLESS_INDEX {
                for val in LOSSLESS_VALUE {
                    let dr = build(idx, val, 1);
                    let back = wire_roundtrip(&dr, sp, g)
                        .map_err(|e| format!("{idx}|{val}: {e}"))?;
                    if &back != sp {
                        return Err(format!(
                            "{idx}|{val}: decode mismatch (nnz {} vs {}, d {})",
                            back.nnz(),
                            sp.nnz(),
                            sp.dense_len()
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn lossy_value_codecs_hold_structural_contracts() {
    forall(
        "codec-matrix-lossy-values",
        12,
        1000,
        gen_case,
        |(g, sp)| {
            let max_abs = sp.values().iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            for val in LOSSY_VALUE {
                let dr = build("raw", val, 1);
                let back =
                    wire_roundtrip(&dr, sp, g).map_err(|e| format!("raw|{val}: {e}"))?;
                if back.dense_len() != sp.dense_len() || back.nnz() != sp.nnz() {
                    return Err(format!(
                        "raw|{val}: shape drift ({}/{} vs {}/{})",
                        back.dense_len(),
                        back.nnz(),
                        sp.dense_len(),
                        sp.nnz()
                    ));
                }
                if back.indices() != sp.indices() {
                    return Err(format!("raw|{val}: support drift"));
                }
                for (&i, &v) in back.indices().iter().zip(back.values()) {
                    if !v.is_finite() {
                        return Err(format!("raw|{val}: non-finite value at {i}"));
                    }
                }
                match val {
                    "fp16" => {
                        if sp.nnz() > 0 && rel_l2_err(sp.values(), back.values()) > 0.05 {
                            return Err(format!(
                                "fp16 rel err {} too large",
                                rel_l2_err(sp.values(), back.values())
                            ));
                        }
                    }
                    "qsgd" => {
                        // quantized magnitudes never exceed the bucket max
                        for &v in back.values() {
                            if v.abs() > max_abs * (1.0 + 1e-5) {
                                return Err(format!("qsgd magnitude {v} > max {max_abs}"));
                            }
                        }
                    }
                    _ => {}
                }
            }
            Ok(())
        },
    );
}

/// Merge-join subset check on sorted index slices.
fn is_subset(sub: &[u32], sup: &[u32]) -> bool {
    let mut j = 0usize;
    for &s in sub {
        while j < sup.len() && sup[j] < s {
            j += 1;
        }
        if j >= sup.len() || sup[j] != s {
            return false;
        }
    }
    true
}

#[test]
fn bloom_policies_hold_support_contracts() {
    forall(
        "codec-matrix-bloom",
        12,
        900,
        gen_case,
        |(g, sp)| {
            for idx in BLOOM_INDEX {
                let dr = build(idx, "raw", 3);
                let back = wire_roundtrip(&dr, sp, g).map_err(|e| format!("{idx}: {e}"))?;
                if back.dense_len() != sp.dense_len() {
                    return Err(format!("{idx}: dense_len drift"));
                }
                match idx {
                    // P0 reconstructs all positives: a superset of S,
                    // with the true gradient value at every position
                    "bloom_p0" => {
                        if !is_subset(sp.indices(), back.indices()) {
                            return Err("bloom_p0: S not a subset of P".into());
                        }
                        for (&i, &v) in back.indices().iter().zip(back.values()) {
                            if v != g[i as usize] {
                                return Err(format!("bloom_p0: value at {i} is {v}"));
                            }
                        }
                    }
                    // P1/P2 pick at most r positions from P, each
                    // carrying its true gradient value
                    "bloom_p1" | "bloom_p2" => {
                        if back.nnz() > sp.nnz().max(1) {
                            return Err(format!(
                                "{idx}: |S̃| = {} exceeds r = {}",
                                back.nnz(),
                                sp.nnz()
                            ));
                        }
                        for (&i, &v) in back.indices().iter().zip(back.values()) {
                            if v != g[i as usize] {
                                return Err(format!("{idx}: value at {i} is {v}"));
                            }
                        }
                    }
                    // Naive reconstructs exactly r positions (the first
                    // r positives) — the mis-assignment is by design
                    "bloom_naive" => {
                        if back.nnz() != sp.nnz() {
                            return Err(format!(
                                "bloom_naive: nnz {} != r {}",
                                back.nnz(),
                                sp.nnz()
                            ));
                        }
                    }
                    _ => unreachable!(),
                }
            }
            Ok(())
        },
    );
}

/// The canonical support shapes chains must survive: nothing, all,
/// one contiguous block, and a periodic cluster comb (long repetitive
/// head-codec streams — the case byte stages exist for).
fn edge_supports(d: usize) -> Vec<Vec<u32>> {
    let full: Vec<u32> = (0..d as u32).collect();
    let block: Vec<u32> = (d as u32 / 4..d as u32 / 2).collect();
    let comb: Vec<u32> = (0..d as u32).filter(|i| (i / 8) % 2 == 0).collect();
    vec![Vec::new(), full, block, comb]
}

#[test]
fn lossless_two_stage_chains_roundtrip_bit_exactly() {
    // every lossless head × byte stage, on both sides of the pipe, over
    // empty / fully-dense / clustered supports, through the full v2
    // container wire
    let mut rng = deepreduce::util::prng::Rng::new(0xC4A1);
    for d in [1usize, 64, 1000] {
        let g = gradient_like(&mut rng, d);
        for support in edge_supports(d) {
            let sp = SparseTensor::gather(&g, &support);
            for idx in LOSSLESS_INDEX {
                for stage in BYTE_STAGES {
                    let spec = format!("{idx}+{stage}");
                    let dr = deepreduce::compress::DeepReduce::builder()
                        .index(&spec)
                        .value("raw")
                        .seed(1)
                        .build()
                        .unwrap_or_else(|e| panic!("{spec}: {e}"));
                    let back = wire_roundtrip(&dr, &sp, &g)
                        .unwrap_or_else(|e| panic!("{spec} d={d}: {e}"));
                    assert_eq!(back, sp, "{spec} d={d} nnz={}", sp.nnz());
                }
            }
            for val in LOSSLESS_VALUE {
                for stage in BYTE_STAGES {
                    let spec = format!("{val}+{stage}");
                    let dr = deepreduce::compress::DeepReduce::builder()
                        .index("raw")
                        .value(&spec)
                        .seed(1)
                        .build()
                        .unwrap_or_else(|e| panic!("{spec}: {e}"));
                    let back = wire_roundtrip(&dr, &sp, &g)
                        .unwrap_or_else(|e| panic!("{spec} d={d}: {e}"));
                    assert_eq!(back, sp, "{spec} d={d} nnz={}", sp.nnz());
                }
            }
        }
    }
}

#[test]
fn chained_lossy_head_keeps_its_contracts() {
    // lossy head + byte tail: the chain is transparent to the head's
    // semantics — fitpoly's reorder perm still travels, bloom_p2's
    // support contract still holds
    let mut rng = deepreduce::util::prng::Rng::new(0xC4A2);
    let d = 900;
    let g = gradient_like(&mut rng, d);
    let support = sorted_support(&mut rng, d, 90);
    let sp = SparseTensor::gather(&g, &support);
    let dr = deepreduce::compress::DeepReduce::builder()
        .index("raw")
        .value("fitpoly+deflate")
        .seed(3)
        .build()
        .unwrap();
    let back = wire_roundtrip(&dr, &sp, &g).unwrap();
    assert_eq!(back.indices(), sp.indices(), "support must survive a value chain");
    assert!(back.values().iter().all(|v| v.is_finite()));

    let dr = deepreduce::compress::DeepReduce::builder()
        .index("bloom_p2(fpr=0.01)+zstd")
        .value("raw")
        .seed(3)
        .build()
        .unwrap();
    let back = wire_roundtrip(&dr, &sp, &g).unwrap();
    assert!(back.nnz() <= sp.nnz().max(1), "P2 cardinality bound through a chain");
    for (&i, &v) in back.indices().iter().zip(back.values()) {
        assert_eq!(v, g[i as usize], "true value at reconstructed position {i}");
    }
}

#[test]
fn full_matrix_empty_and_fully_dense_edges() {
    let mut rng = deepreduce::util::prng::Rng::new(0xEDCE);
    for d in [1usize, 63, 300] {
        let g = gradient_like(&mut rng, d);
        let empty = SparseTensor::new(d, Vec::new(), Vec::new());
        let full_support: Vec<u32> = (0..d as u32).collect();
        let full = SparseTensor::gather(&g, &full_support);
        let all_index = LOSSLESS_INDEX.iter().chain(BLOOM_INDEX.iter());
        for &idx in all_index {
            for &val in LOSSLESS_VALUE.iter().chain(LOSSY_VALUE.iter()) {
                let dr = build(idx, val, 5);
                // empty: every pair must produce a decodable container
                // with zero entries
                let back = wire_roundtrip(&dr, &empty, &g)
                    .unwrap_or_else(|e| panic!("{idx}|{val} empty d={d}: {e}"));
                assert_eq!(back.nnz(), 0, "{idx}|{val} empty d={d}");
                assert_eq!(back.dense_len(), d, "{idx}|{val} empty d={d}");
                // fully dense: must decode; lossless pairs bit-exactly
                let back = wire_roundtrip(&dr, &full, &g)
                    .unwrap_or_else(|e| panic!("{idx}|{val} full d={d}: {e}"));
                assert_eq!(back.dense_len(), d, "{idx}|{val} full d={d}");
                if LOSSLESS_INDEX.contains(&idx) && LOSSLESS_VALUE.contains(&val) {
                    assert_eq!(back, full, "{idx}|{val} full d={d}");
                }
            }
        }
    }
}
