//! Observability validation (DESIGN.md §11):
//!
//! 1. **Golden span sequence** — a 2-rank GatherAll on the virtual
//!    fabric produces a pinned cpu-lane span sequence per rank
//!    (pack → recv_wait → decode → merge) plus one egress and one
//!    ingress port booking, with a positive virtual wait.
//! 2. **Chrome-trace export round-trip** — `TraceReport::to_json`
//!    serialises, re-parses through `util::json`, and carries the
//!    schema version, clock tag, and well-formed `traceEvents`.
//! 3. **Nesting property** — every schedule's full-level trace forms a
//!    proper tree per (rank, lane, clock) under `check_nesting`.
//! 4. **Reconciliation by construction** — the virtual clock only
//!    advances through elapse / recv-wait, so compute + wait + barrier
//!    attribution on the slowest rank explains the whole measured step.

use deepreduce::collective::{Schedule, SparseConfig, Topology};
use deepreduce::obs::{
    self, check_nesting, Lane, Span, SpanKind, StepWindow, TraceLevel, TraceReport, Tracer,
};
use deepreduce::simnet::Link;
use deepreduce::tensor::SparseTensor;
use deepreduce::util::json::Json;
use deepreduce::vfabric::{Scenario, VirtualNetwork};
use std::collections::BTreeMap;
use std::sync::Arc;
use std::thread;

/// A slow-enough link that every transfer takes visible virtual time.
fn slow_link() -> Link {
    Link { bandwidth_bps: 1e6, latency_s: 1e-3 }
}

/// Disjoint strided supports so merges are non-trivial on every rank.
fn inputs(n: usize, d: usize, k: usize) -> Vec<SparseTensor> {
    (0..n)
        .map(|r| {
            let idx: Vec<u32> = (0..k).map(|j| ((j * n + r) % d) as u32).collect();
            let val: Vec<f32> = (0..k).map(|j| 1.0 + (r * k + j) as f32 / 10.0).collect();
            SparseTensor::new(d, idx, val)
        })
        .collect()
}

/// Run `sched` on a fully-traced virtual fabric; returns the drained
/// spans (step-stamped 0) and the fabric's critical path.
fn run_traced(
    sched: Schedule,
    cfg: SparseConfig,
    topo: Topology,
    tracer: &Arc<Tracer>,
) -> (Vec<Span>, f64) {
    let n = topo.world();
    let net = VirtualNetwork::new(topo, slow_link(), slow_link(), Scenario::none(0));
    let handles: Vec<_> = net
        .endpoints()
        .into_iter()
        .zip(inputs(n, 512, 16))
        .enumerate()
        .map(|(r, (ep, t))| {
            let tracer = tracer.clone();
            thread::spawn(move || {
                let _bind = tracer.install(r);
                sched.build(cfg).allreduce(&ep, t).unwrap()
                // InstallGuard drop flushes this thread's buffer
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    (tracer.drain(0), net.max_clock_s())
}

/// (1) the golden fixture: exact per-rank cpu-lane anatomy of a 2-rank
/// GatherAll, pinned so instrumentation cannot silently drift.
#[test]
fn golden_gather_all_two_rank_span_sequence() {
    let tracer = Tracer::new(TraceLevel::Full, 2);
    let (spans, _) = run_traced(
        Schedule::GatherAll,
        SparseConfig::default(),
        Topology::flat(2),
        &tracer,
    );
    for r in 0..2u32 {
        let cpu: Vec<SpanKind> = spans
            .iter()
            .filter(|s| s.rank == r && s.lane == Lane::Cpu)
            .map(|s| s.kind)
            .collect();
        assert_eq!(
            cpu,
            vec![SpanKind::Pack, SpanKind::RecvWait, SpanKind::Decode, SpanKind::Merge],
            "rank {r} cpu lane"
        );
        let sends: Vec<&Span> = spans
            .iter()
            .filter(|s| s.rank == r && s.lane == Lane::EgressIntra)
            .collect();
        let recvs: Vec<&Span> = spans
            .iter()
            .filter(|s| s.rank == r && s.lane == Lane::IngressIntra)
            .collect();
        assert_eq!(sends.len(), 1, "rank {r} egress bookings");
        assert_eq!(recvs.len(), 1, "rank {r} ingress bookings");
        assert_eq!(sends[0].kind, SpanKind::Send);
        assert_eq!(recvs[0].kind, SpanKind::Recv);
        assert!(sends[0].bytes > 0 && sends[0].virt_dur() > 0.0);
        // both ranks start at virtual 0 and send first, so each one
        // waits at least the link latency for the peer's message
        let wait = spans
            .iter()
            .find(|s| s.rank == r && s.kind == SpanKind::RecvWait)
            .unwrap();
        assert!(wait.has_virtual(), "recv_wait must carry virtual stamps");
        assert!(wait.virt_dur() >= 1e-3, "rank {r} waited {}s", wait.virt_dur());
    }
    check_nesting(&spans).unwrap();
    // registry sees one pack/decode per rank
    assert_eq!(tracer.registry().counter("wire.pack_calls").get(), 2);
    assert_eq!(tracer.registry().counter("wire.decode_calls").get(), 2);
    assert_eq!(tracer.registry().counter("sched.gather_all_steps").get(), 2);
    assert!(tracer.registry().counter("vfabric.intra_bytes").get() > 0);
}

/// (2) the exported artifact re-parses through the repo's own JSON
/// parser and keeps the schema/clock contract.
#[test]
fn chrome_export_roundtrips_through_json_parser() {
    let tracer = Tracer::new(TraceLevel::Full, 2);
    let (spans, critical_path) = run_traced(
        Schedule::GatherAll,
        SparseConfig::default(),
        Topology::flat(2),
        &tracer,
    );
    let n_spans = spans.len();
    let report = TraceReport {
        name: "golden".to_string(),
        level: TraceLevel::Full,
        ranks: 2,
        meta: BTreeMap::from([(
            "schedule".to_string(),
            Json::Str("gather_all".to_string()),
        )]),
        steps: vec![StepWindow {
            step: 0,
            measured_s: critical_path,
            idle_mean_s: f64::NAN,
            virt0: 0.0,
            virt1: critical_path,
        }],
        spans,
        registry: tracer.registry().snapshot(),
    };
    let text = report.to_json().to_string();
    let parsed = Json::parse(&text).expect("trace JSON must re-parse");
    assert_eq!(parsed.get("schema_version").unwrap().as_f64(), Some(1.0));
    assert_eq!(parsed.get("clock").unwrap().as_str(), Some("virtual"));
    assert_eq!(parsed.get("ranks").unwrap().as_f64(), Some(2.0));
    assert_eq!(parsed.get("schedule").unwrap().as_str(), Some("gather_all"));
    assert_eq!(parsed.get("spans").unwrap().as_arr().unwrap().len(), n_spans);
    // Chrome trace_event contract: every X event is a complete interval
    // on a known (pid=rank, tid=lane) pair; metadata names the lanes
    let events = parsed.get("traceEvents").unwrap().as_arr().unwrap();
    assert!(!events.is_empty());
    let mut x_events = 0;
    let mut thread_names = 0;
    for e in events {
        match e.get("ph").unwrap().as_str().unwrap() {
            "X" => {
                x_events += 1;
                let pid = e.get("pid").unwrap().as_f64().unwrap();
                let tid = e.get("tid").unwrap().as_f64().unwrap();
                assert!(pid < 2.0, "pid is a rank");
                assert!(tid <= Lane::IngressInter.tid() as f64, "tid is a lane");
                assert!(e.get("dur").unwrap().as_f64().unwrap() >= 0.0);
            }
            "M" => {
                if e.get("name").unwrap().as_str() == Some("thread_name") {
                    thread_names += 1;
                }
            }
            other => panic!("unexpected phase {other}"),
        }
    }
    assert!(x_events > 0, "no interval events exported");
    assert!(thread_names > 0, "lanes must be named for Perfetto");
    // registry snapshot rode along
    assert!(parsed.get("registry").unwrap().get("counters").is_some());
    // and the terminal summary renders without panicking
    let summary = report.summary();
    assert!(summary.contains("golden"), "{summary}");
    assert!(summary.contains("slowest rank"), "{summary}");
}

/// (3) nesting property: every schedule's full-level trace is a proper
/// tree per (rank, lane, clock) — rounds contain their packs/waits,
/// nothing straddles a sibling.
#[test]
fn span_trees_nest_for_every_schedule() {
    let cases: Vec<(Schedule, Topology)> = vec![
        (Schedule::GatherAll, Topology::flat(4)),
        (Schedule::RecursiveDouble, Topology::flat(4)),
        // non-power-of-two exercises the fold/unfold pre-rounds
        (Schedule::RecursiveDouble, Topology::flat(3)),
        (Schedule::RingRescatter, Topology::flat(4)),
        // chunks=8 forces two sub-chunks per rank at n=4 (three at
        // n=3), so the streamed encoder lane and the per-round frame
        // interleave are both exercised
        (Schedule::ChunkedRescatter, Topology::flat(4)),
        (Schedule::ChunkedRescatter, Topology::flat(3)),
        (Schedule::Hierarchical, Topology::new(2, 2)),
    ];
    for (sched, topo) in cases {
        let cfg = SparseConfig {
            topology: (sched == Schedule::Hierarchical).then_some(topo),
            chunks: if sched == Schedule::ChunkedRescatter { 8 } else { 0 },
            ..SparseConfig::default()
        };
        let tracer = Tracer::new(TraceLevel::Full, topo.world());
        let (spans, _) = run_traced(sched, cfg, topo, &tracer);
        assert!(!spans.is_empty(), "{} produced no spans", sched.name());
        if let Err(e) = check_nesting(&spans) {
            panic!("{} violates span nesting: {e}", sched.name());
        }
    }
}

/// (4) reconciliation: compute + recv-wait + barrier attribution on the
/// slowest rank explains (essentially all of) the measured virtual step
/// — the invariant the `--trace-summary` coverage column relies on.
#[test]
fn attribution_reconciles_virtual_step_time() {
    let n = 4usize;
    let tracer = Tracer::new(TraceLevel::Full, n);
    let net = VirtualNetwork::new(
        Topology::flat(n),
        slow_link(),
        slow_link(),
        Scenario::none(0),
    );
    let handles: Vec<_> = net
        .endpoints()
        .into_iter()
        .zip(inputs(n, 512, 16))
        .enumerate()
        .map(|(r, (ep, t))| {
            let tracer = tracer.clone();
            thread::spawn(move || {
                let _bind = tracer.install(r);
                ep.sync_to(0.0); // publish the clock so compute gets virtual stamps
                {
                    let mut sp = obs::span(SpanKind::Compute);
                    sp.label_with(|| "replay".to_string());
                    // rank 0 is a 4x straggler
                    ep.elapse(if r == 0 { 0.040 } else { 0.010 });
                }
                Schedule::GatherAll
                    .build(SparseConfig::default())
                    .allreduce(&ep, t)
                    .unwrap();
                ep.now()
            })
        })
        .collect();
    let ends: Vec<f64> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    let step_end = ends.iter().copied().fold(0.0, f64::max);
    // synthesise the end-of-step barrier gap per rank, as the trainer does
    for (r, &e) in ends.iter().enumerate() {
        tracer.record(Span {
            kind: SpanKind::Barrier,
            lane: Lane::Cpu,
            rank: r as u32,
            step: 0,
            depth: 0,
            bytes: 0,
            label: None,
            wall0: f64::NAN,
            wall1: f64::NAN,
            virt0: e,
            virt1: step_end,
        });
    }
    let report = TraceReport {
        name: "reconcile".to_string(),
        level: TraceLevel::Full,
        ranks: n,
        meta: BTreeMap::new(),
        steps: vec![StepWindow {
            step: 0,
            measured_s: step_end,
            idle_mean_s: f64::NAN,
            virt0: 0.0,
            virt1: step_end,
        }],
        spans: tracer.drain(0),
        registry: tracer.registry().snapshot(),
    };
    let coverage = report.reconciliation(0).expect("virtual data present");
    // the virtual clock only advances through elapse and recv-wait, so
    // the decomposition is exact up to float summation
    assert!(
        (coverage - 1.0).abs() < 1e-6,
        "attribution explains {:.4} of the step, expected ~1.0",
        coverage
    );
}

/// (5) fleet-path coverage pin: the fleet runner instruments the step
/// as per-rank Compute/Exchange/Barrier (recv-waits nest *inside* the
/// exchange), and `--trace-summary` must attribute over exactly that
/// partition. The regression this guards: attributing over
/// Compute + RecvWait + Barrier on a trace that carries Exchange spans
/// either double-counts the nested waits or mis-reports coverage for
/// lanes the run never instruments.
#[test]
fn fleet_style_exchange_trace_reconciles_exactly() {
    let n = 4usize;
    let tracer = Tracer::new(TraceLevel::Full, n);
    let step_end = 1.0;
    for r in 0..n {
        let c1 = 0.1 * (r + 1) as f64; // compute ends (rank-staggered)
        let e1 = 0.6 + 0.05 * r as f64; // exchange ends
        let mk = |kind, v0: f64, v1: f64| Span {
            kind,
            lane: Lane::Cpu,
            rank: r as u32,
            step: 0,
            depth: 0,
            bytes: 0,
            label: None,
            wall0: f64::NAN,
            wall1: f64::NAN,
            virt0: v0,
            virt1: v1,
        };
        tracer.record(mk(SpanKind::Compute, 0.0, c1));
        tracer.record(mk(SpanKind::Exchange, c1, e1));
        // interior wait: already inside the exchange interval, must not
        // be attributed a second time
        tracer.record(mk(SpanKind::RecvWait, c1, (c1 + 0.1).min(e1)));
        tracer.record(mk(SpanKind::Barrier, e1, step_end));
    }
    let report = TraceReport {
        name: "fleet_style".to_string(),
        level: TraceLevel::Full,
        ranks: n,
        meta: BTreeMap::new(),
        steps: vec![StepWindow {
            step: 0,
            measured_s: step_end,
            idle_mean_s: f64::NAN,
            virt0: 0.0,
            virt1: step_end,
        }],
        spans: tracer.drain(0),
        registry: tracer.registry().snapshot(),
    };
    let coverage = report.reconciliation(0).expect("virtual data present");
    assert!(
        (coverage - 1.0).abs() < 1e-9,
        "exchange-partition coverage is {coverage:.6}, expected exactly 1.0"
    );
    // the summary names the exchange column when exchange spans exist
    let summary = report.summary();
    assert!(summary.contains("exchange"), "{summary}");
}
