//! Property tests for the sparse allreduce schedules: every schedule
//! must produce the dense ring allreduce sum — exactly for the exact
//! schedules, and per the per-chunk top-⌈k/n⌉ contract when
//! `ring_rescatter` re-sparsifies. The hierarchical schedule is pinned
//! *byte-identical* to GatherAll on integer-valued gradients across
//! node shapes (where f32 addition is exact in any association order).
//! Runs entirely on the in-process fabric; no artifacts required.

use deepreduce::collective::sparse::merge;
use deepreduce::collective::{all_reduce_ring, Network, Schedule, SparseConfig, Topology};
use deepreduce::tensor::SparseTensor;
use deepreduce::util::prng::Rng;
use deepreduce::util::testkit::{forall, sorted_support};
use std::thread;

/// Run one schedule across `inputs.len()` worker threads; returns every
/// rank's result in rank order.
fn run_schedule(sched: Schedule, inputs: &[SparseTensor]) -> Vec<SparseTensor> {
    run_with(sched, SparseConfig::default(), inputs)
}

/// Like [`run_schedule`] with explicit tuning (topology, inner
/// schedule); the fabric carries the config's grid when one is set.
fn run_with(sched: Schedule, cfg: SparseConfig, inputs: &[SparseTensor]) -> Vec<SparseTensor> {
    let net = match cfg.topology {
        Some(topo) => Network::with_topology(topo),
        None => Network::new(inputs.len()),
    };
    let handles: Vec<_> = net
        .endpoints()
        .into_iter()
        .zip(inputs.to_vec())
        .map(|(ep, t)| thread::spawn(move || sched.build(cfg).allreduce(&ep, t).unwrap()))
        .collect();
    handles.into_iter().map(|h| h.join().unwrap()).collect()
}

/// Reference: densify and run the existing dense ring allreduce.
fn dense_reference(inputs: &[SparseTensor]) -> Vec<f32> {
    let net = Network::new(inputs.len());
    let handles: Vec<_> = net
        .endpoints()
        .into_iter()
        .zip(inputs.to_vec())
        .map(|(ep, t)| {
            thread::spawn(move || {
                let mut x = t.to_dense().into_vec();
                all_reduce_ring(&ep, &mut x);
                x
            })
        })
        .collect();
    let mut outs: Vec<Vec<f32>> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    outs.pop().unwrap()
}

fn random_inputs(rng: &mut Rng, n: usize, d: usize) -> Vec<SparseTensor> {
    (0..n)
        .map(|_| {
            let k = rng.below(d as u64 + 1) as usize;
            let support = sorted_support(rng, d, k);
            let values: Vec<f32> = (0..k).map(|_| rng.next_gaussian() as f32).collect();
            SparseTensor::new(d, support, values)
        })
        .collect()
}

#[test]
fn exact_schedules_match_dense_ring_allreduce() {
    forall(
        "sparse-allreduce-dense-equiv",
        30,
        600,
        |rng, size| {
            let n = 1 + rng.below(8) as usize;
            let d = 1 + rng.below(size as u64) as usize;
            random_inputs(rng, n, d)
        },
        |inputs| {
            let reference = dense_reference(inputs);
            for sched in
                [Schedule::GatherAll, Schedule::RecursiveDouble, Schedule::RingRescatterExact]
            {
                for (rank, out) in run_schedule(sched, inputs).iter().enumerate() {
                    if out.dense_len() != inputs[0].dense_len() {
                        return Err(format!("{sched:?}: wrong dense_len on rank {rank}"));
                    }
                    let dense = out.to_dense();
                    for (i, (&a, &b)) in dense.data().iter().zip(&reference).enumerate() {
                        if (a - b).abs() > 1e-3 * (1.0 + b.abs()) {
                            return Err(format!(
                                "{sched:?} rank {rank} index {i}: {a} vs dense {b}"
                            ));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn recursive_double_bitwise_identical_across_ranks() {
    // merge order is symmetric at every doubling round, so all ranks of a
    // power-of-two world converge on bit-identical sums
    let mut rng = Rng::new(0xD0B1);
    for n in [2usize, 4, 8] {
        let inputs = random_inputs(&mut rng, n, 500);
        let outs = run_schedule(Schedule::RecursiveDouble, &inputs);
        for o in &outs[1..] {
            assert_eq!(o, &outs[0], "n={n}");
        }
    }
}

#[test]
fn ring_rescatter_resparsify_keeps_per_chunk_topk() {
    let mut rng = Rng::new(0xC44);
    for &(n, d, k) in &[(4usize, 1000usize, 100usize), (8, 4096, 256), (3, 77, 20)] {
        let inputs: Vec<SparseTensor> = (0..n)
            .map(|_| {
                let support = sorted_support(&mut rng, d, k);
                let values: Vec<f32> = (0..k).map(|_| rng.next_gaussian() as f32).collect();
                SparseTensor::new(d, support, values)
            })
            .collect();
        let outs = run_schedule(Schedule::RingRescatter, &inputs);
        // chunk contents are owner-determined: all ranks agree exactly
        for o in &outs[1..] {
            assert_eq!(o, &outs[0], "n={n} d={d}");
        }
        let out = &outs[0];
        // direct (order-independent) sum for value checks
        let mut direct = vec![0.0f32; d];
        for t in &inputs {
            t.add_into(&mut direct);
        }
        let bounds = merge::chunk_bounds(d, n);
        let r = k.div_ceil(n);
        for c in 0..n {
            let (lo, hi) = (bounds[c], bounds[c + 1]);
            let chunk = merge::slice_range(out, lo, hi);
            // kept set is capped at ⌈k/n⌉ and maximal wrt the union support
            let mut union: Vec<u32> = inputs
                .iter()
                .flat_map(|t| merge::slice_range(t, lo, hi).indices().to_vec())
                .collect();
            union.sort_unstable();
            union.dedup();
            assert_eq!(
                chunk.nnz(),
                r.min(union.len()),
                "n={n} d={d} chunk {c}: kept {} of union {}",
                chunk.nnz(),
                union.len()
            );
            // every kept value is the true sum at its index
            for (&i, &v) in chunk.indices().iter().zip(chunk.values()) {
                let want = direct[i as usize];
                assert!(
                    (v - want).abs() <= 1e-3 * (1.0 + want.abs()),
                    "n={n} chunk {c} index {i}: {v} vs {want}"
                );
            }
        }
    }
}

#[test]
fn ring_rescatter_budget_survives_empty_rank_input() {
    // rank 0 contributes nothing; the chunk it owns must still keep the
    // other ranks' reduced gradients — the re-sparsification budget is
    // the global max input nnz carried around the ring, not the owner's
    // local (zero) nnz
    let n = 4;
    let d = 400;
    let k = 40;
    let mut rng = Rng::new(0xE77);
    let mut inputs = vec![SparseTensor::new(d, Vec::new(), Vec::new())];
    for _ in 1..n {
        let support = sorted_support(&mut rng, d, k);
        let values: Vec<f32> = (0..k).map(|_| rng.next_gaussian() as f32).collect();
        inputs.push(SparseTensor::new(d, support, values));
    }
    let outs = run_schedule(Schedule::RingRescatter, &inputs);
    for o in &outs[1..] {
        assert_eq!(o, &outs[0]);
    }
    // rank 0 owns chunk 1 = [100, 200): with 120 random entries over
    // d=400 the chunk is nonempty with overwhelming probability, and its
    // kept entries must survive re-sparsification
    let bounds = merge::chunk_bounds(d, n);
    let own_chunk = merge::slice_range(&outs[0], bounds[1], bounds[2]);
    assert!(own_chunk.nnz() > 0, "empty-input owner zeroed its chunk");
    // budget is ceil(max_k/n) = 10 per chunk
    assert!(own_chunk.nnz() <= k.div_ceil(n));
}

/// Randomized differential test: the topology-aware schedules must be
/// dense-equivalent to the GatherAll baseline (the paper's exchange)
/// across seeds, rank counts 2–8 including non-powers-of-two, and
/// densities from empty to fully dense.
#[test]
fn randomized_differential_vs_gather_all() {
    for seed in [0xD1FF_0001u64, 0xD1FF_0002, 0xD1FF_0003] {
        let mut rng = Rng::new(seed);
        for n in 2usize..=8 {
            for &density in &[0.0f64, 0.02, 0.1, 0.5, 1.0] {
                let d = 64 + rng.below(1000) as usize;
                let k = ((d as f64 * density) as usize).min(d);
                let inputs: Vec<SparseTensor> = (0..n)
                    .map(|_| {
                        let support = sorted_support(&mut rng, d, k);
                        let values: Vec<f32> =
                            (0..support.len()).map(|_| rng.next_gaussian() as f32).collect();
                        SparseTensor::new(d, support, values)
                    })
                    .collect();
                // reference: the GatherAll schedule itself (not the dense
                // ring) — this pins RecursiveDouble / RingRescatter to
                // the baseline they claim to replace
                let reference = run_schedule(Schedule::GatherAll, &inputs)
                    .pop()
                    .unwrap()
                    .to_dense();
                for sched in [Schedule::RecursiveDouble, Schedule::RingRescatterExact] {
                    for (rank, out) in run_schedule(sched, &inputs).iter().enumerate() {
                        assert_eq!(out.dense_len(), d, "{sched:?} rank {rank}");
                        let dense = out.to_dense();
                        for (i, (&a, &b)) in
                            dense.data().iter().zip(reference.data()).enumerate()
                        {
                            assert!(
                                (a - b).abs() <= 1e-3 * (1.0 + b.abs()),
                                "seed {seed:#x} n={n} density={density} {sched:?} \
                                 rank {rank} index {i}: {a} vs gather_all {b}"
                            );
                        }
                    }
                }
                // the re-sparsifying schedule keeps a subset, but every
                // kept value must be the GatherAll sum at that index
                for (rank, out) in run_schedule(Schedule::RingRescatter, &inputs).iter().enumerate()
                {
                    for (&i, &v) in out.indices().iter().zip(out.values()) {
                        let want = reference.data()[i as usize];
                        assert!(
                            (v - want).abs() <= 1e-3 * (1.0 + want.abs()),
                            "seed {seed:#x} n={n} density={density} ring_rescatter \
                             rank {rank} index {i}: {v} vs gather_all {want}"
                        );
                    }
                }
            }
        }
    }
}

/// Random support with positive small-integer values: f32 addition over
/// such values is exact in ANY association order, so schedules that
/// claim the same sum must agree bit-for-bit, not just within an
/// epsilon.
fn integer_inputs(rng: &mut Rng, n: usize, d: usize) -> Vec<SparseTensor> {
    (0..n)
        .map(|_| {
            let k = rng.below(d as u64 + 1) as usize;
            let support = sorted_support(rng, d, k);
            let values: Vec<f32> = (0..k).map(|_| (1 + rng.below(15)) as f32).collect();
            SparseTensor::new(d, support, values)
        })
        .collect()
}

/// The acceptance pin of the hierarchical schedule: across node shapes
/// — 1×n (one node), n×1 (every rank a leader), square and non-square
/// grids including non-powers-of-two — and every exact inner schedule,
/// the result must be *byte-identical* to the GatherAll baseline on
/// every rank.
#[test]
fn hierarchical_byte_identical_to_gather_all_across_node_shapes() {
    let mut rng = Rng::new(0x21E7);
    for (nodes, rpn) in [(1usize, 5usize), (5, 1), (2, 4), (3, 3), (2, 2), (2, 3), (4, 2)] {
        let topo = Topology::new(nodes, rpn);
        let n = topo.world();
        for _ in 0..3 {
            let d = 30 + rng.below(400) as usize;
            let inputs = integer_inputs(&mut rng, n, d);
            let reference = run_schedule(Schedule::GatherAll, &inputs);
            for inner in [
                Schedule::GatherAll,
                Schedule::RecursiveDouble,
                Schedule::RingRescatterExact,
                Schedule::ChunkedRescatter,
            ] {
                let cfg = SparseConfig {
                    topology: Some(topo),
                    inner,
                    ..SparseConfig::default()
                };
                let outs = run_with(Schedule::Hierarchical, cfg, &inputs);
                for (rank, (out, want)) in outs.iter().zip(&reference).enumerate() {
                    assert_eq!(
                        out, want,
                        "{nodes}x{rpn} inner {inner:?} rank {rank} diverged from gather_all"
                    );
                }
            }
        }
    }
}

/// The acceptance pin of the chunked schedule: across world sizes 2–8
/// (non-powers-of-two included) and chunk counts {auto, 1, P, 4P} (the
/// knob rounds up to a multiple of the world size), the result must be
/// *byte-identical* to GatherAll on integer-valued gradients on every
/// rank — no re-sparsification, no merge-order divergence.
#[test]
fn chunked_byte_identical_to_gather_all() {
    let mut rng = Rng::new(0xC4C4);
    for n in 2usize..=8 {
        let d = 30 + rng.below(400) as usize;
        let inputs = integer_inputs(&mut rng, n, d);
        let reference = run_schedule(Schedule::GatherAll, &inputs);
        for chunks in [0usize, 1, n, 4 * n] {
            let cfg = SparseConfig { chunks, ..SparseConfig::default() };
            let outs = run_with(Schedule::ChunkedRescatter, cfg, &inputs);
            for (rank, (out, want)) in outs.iter().zip(&reference).enumerate() {
                assert_eq!(
                    out, want,
                    "n={n} chunks={chunks} rank {rank} diverged from gather_all"
                );
            }
        }
    }
}

/// Heavily clustered supports: the balanced bounds subdivide the hot
/// region and leave most of the domain in empty chunks — empty-chunk
/// frames and fully-dense sub-chunk frames must both survive, and the
/// sum stays byte-identical to GatherAll.
#[test]
fn chunked_balances_skewed_support_with_empty_chunks() {
    let d = 4096usize;
    for n in [3usize, 4, 8] {
        // every rank's support lives in the first 1/16 of the domain,
        // fully dense there — the equal-width partition would hand
        // chunk 0 everything
        let hot = d / 16;
        let inputs: Vec<SparseTensor> = (0..n)
            .map(|r| {
                let idx: Vec<u32> = (0..hot as u32).collect();
                let val: Vec<f32> = (0..hot).map(|i| ((i + r) % 7 + 1) as f32).collect();
                SparseTensor::new(d, idx, val)
            })
            .collect();
        let reference = run_schedule(Schedule::GatherAll, &inputs);
        for chunks in [0usize, 4 * n] {
            let cfg = SparseConfig { chunks, ..SparseConfig::default() };
            let outs = run_with(Schedule::ChunkedRescatter, cfg, &inputs);
            for (rank, (out, want)) in outs.iter().zip(&reference).enumerate() {
                assert_eq!(out, want, "n={n} chunks={chunks} rank {rank}");
            }
        }
    }
}

/// An empty rank contributes an all-zero histogram and empty frames;
/// the remaining ranks' sum must still come through untouched.
#[test]
fn chunked_survives_empty_rank_input() {
    let mut rng = Rng::new(0xC4C5);
    let n = 5;
    let d = 300;
    let mut inputs = integer_inputs(&mut rng, n, d);
    inputs[0] = SparseTensor::new(d, Vec::new(), Vec::new());
    let reference = run_schedule(Schedule::GatherAll, &inputs);
    let outs = run_schedule(Schedule::ChunkedRescatter, &inputs);
    for (rank, (out, want)) in outs.iter().zip(&reference).enumerate() {
        assert_eq!(out, want, "rank {rank}");
    }
}

/// Gaussian-valued differential test (tolerance-based, where f32
/// association noise is expected): hierarchical must match the dense
/// ring allreduce on every rank, for every node shape and inner.
#[test]
fn hierarchical_matches_dense_reference_gaussian() {
    let mut rng = Rng::new(0x21E8);
    for (nodes, rpn) in [(2usize, 4usize), (3, 3), (2, 3), (4, 2)] {
        let topo = Topology::new(nodes, rpn);
        let n = topo.world();
        let d = 64 + rng.below(500) as usize;
        let inputs = random_inputs(&mut rng, n, d);
        let reference = dense_reference(&inputs);
        for inner in [
            Schedule::GatherAll,
            Schedule::RecursiveDouble,
            Schedule::RingRescatterExact,
            Schedule::ChunkedRescatter,
        ] {
            let cfg = SparseConfig { topology: Some(topo), inner, ..SparseConfig::default() };
            for (rank, out) in run_with(Schedule::Hierarchical, cfg, &inputs).iter().enumerate() {
                let dense = out.to_dense();
                for (i, (&a, &b)) in dense.data().iter().zip(&reference).enumerate() {
                    assert!(
                        (a - b).abs() <= 1e-3 * (1.0 + b.abs()),
                        "{nodes}x{rpn} inner {inner:?} rank {rank} index {i}: {a} vs {b}"
                    );
                }
            }
        }
    }
}

/// With the lossy ring as the inner schedule the result keeps a subset
/// of the union, but every kept value must still be the exact node-sum
/// aggregate (same contract as the flat lossy ring), and all ranks must
/// agree bit-for-bit.
#[test]
fn hierarchical_lossy_inner_keeps_true_sums() {
    let mut rng = Rng::new(0x21E9);
    for (nodes, rpn) in [(2usize, 4usize), (4, 2), (3, 3)] {
        let topo = Topology::new(nodes, rpn);
        let n = topo.world();
        let d = 400;
        let inputs = integer_inputs(&mut rng, n, d);
        let reference = run_schedule(Schedule::GatherAll, &inputs).pop().unwrap().to_dense();
        let cfg = SparseConfig {
            topology: Some(topo),
            inner: Schedule::RingRescatter,
            ..SparseConfig::default()
        };
        let outs = run_with(Schedule::Hierarchical, cfg, &inputs);
        for o in &outs[1..] {
            assert_eq!(o, &outs[0], "{nodes}x{rpn}: ranks disagree");
        }
        for (&i, &v) in outs[0].indices().iter().zip(outs[0].values()) {
            let want = reference.data()[i as usize];
            assert_eq!(v, want, "{nodes}x{rpn} index {i}: kept {v} vs sum {want}");
        }
    }
}

/// The fabric's per-class meters: on a grid, the hierarchical schedule
/// crosses nodes only with leader traffic, and a 1×n grid crosses
/// never.
#[test]
fn hierarchical_link_class_accounting() {
    let mut rng = Rng::new(0x21EA);
    let d = 500;
    let inputs = integer_inputs(&mut rng, 8, d);
    // 1×8: no inter-node traffic at all
    let topo = Topology::new(1, 8);
    let net = Network::with_topology(topo);
    let cfg = SparseConfig { topology: Some(topo), ..SparseConfig::default() };
    let handles: Vec<_> = net
        .endpoints()
        .into_iter()
        .zip(inputs.to_vec())
        .map(|(ep, t)| {
            thread::spawn(move || Schedule::Hierarchical.build(cfg).allreduce(&ep, t).unwrap())
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    assert!(net.total_bytes() > 0);
    assert_eq!(net.inter_bytes(), 0, "single-node grid must never cross nodes");
    assert_eq!(net.intra_bytes(), net.total_bytes());
}

#[test]
fn world_size_one_is_identity_for_every_schedule() {
    for sched in Schedule::all() {
        let t = SparseTensor::new(10, vec![2, 5], vec![1.0, -2.0]);
        let outs = run_schedule(sched, &[t.clone()]);
        assert_eq!(outs, vec![t], "{sched:?}");
    }
}

#[test]
fn all_empty_tensors_stay_empty() {
    for sched in Schedule::all() {
        let inputs: Vec<SparseTensor> =
            (0..4).map(|_| SparseTensor::new(50, Vec::new(), Vec::new())).collect();
        for out in run_schedule(sched, &inputs) {
            assert_eq!(out.nnz(), 0, "{sched:?}");
            assert_eq!(out.dense_len(), 50);
        }
    }
}

#[test]
fn domain_smaller_than_world_size() {
    // d < n: most ring chunks are empty, recursive doubling unions a
    // handful of indices — sums must still be exact
    let n = 6;
    let d = 3;
    let inputs: Vec<SparseTensor> =
        (0..n).map(|r| SparseTensor::new(d, vec![(r % d) as u32], vec![1.0])).collect();
    for sched in Schedule::all() {
        for out in run_schedule(sched, &inputs) {
            assert_eq!(out.to_dense().data(), &[2.0, 2.0, 2.0], "{sched:?}");
        }
    }
}

#[test]
fn full_density_triggers_dense_switch_and_stays_exact() {
    // density 1.0 on every rank: recursive doubling ships dense segments
    // from round one; results must be exact (small integers in f32)
    let n = 4;
    let d = 64;
    let inputs: Vec<SparseTensor> = (0..n)
        .map(|r| {
            let idx: Vec<u32> = (0..d as u32).collect();
            let val: Vec<f32> = (0..d).map(|i| (i + r + 1) as f32).collect();
            SparseTensor::new(d, idx, val)
        })
        .collect();
    let expected: Vec<f32> = (0..d).map(|i| (4 * i + 1 + 2 + 3 + 4) as f32).collect();
    for sched in Schedule::all() {
        for out in run_schedule(sched, &inputs) {
            assert_eq!(out.to_dense().data(), expected.as_slice(), "{sched:?}");
        }
    }
}
