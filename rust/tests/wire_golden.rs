//! Golden wire-format fixture tests: byte-exact snapshots of
//! `collective/sparse/wire.rs` segments and `compress/container.rs`
//! blobs, so any format drift fails loudly instead of silently breaking
//! cross-version interop.
//!
//! The expected bytes were derived independently from the documented
//! formats (doc-comments of `SegmentCodec` and `Container`): LEB128
//! varints, little-endian f32/u32, LSB-first bit packing, IEEE CRC-32.
//! If one of these tests fails, either the wire format changed (bump
//! the format docs AND regenerate the fixtures deliberately) or an
//! encoder regressed.

use deepreduce::collective::sparse::SegmentCodec;
use deepreduce::compress::Container;
use deepreduce::tensor::SparseTensor;

fn unhex(s: &str) -> Vec<u8> {
    assert!(s.len() % 2 == 0);
    (0..s.len())
        .step_by(2)
        .map(|i| u8::from_str_radix(&s[i..i + 2], 16).expect("hex fixture"))
        .collect()
}

fn st(d: usize, iv: &[(u32, f32)]) -> SparseTensor {
    SparseTensor::new(
        d,
        iv.iter().map(|&(i, _)| i).collect(),
        iv.iter().map(|&(_, v)| v).collect(),
    )
}

/// sparse segment: tag 0 | lo=20 | hi=40 | nnz=3 | raw local u32 idx |
/// raw f32 values
const SEG_SPARSE: &str = "001428030c0000000005000000130000000c0000c03f000000c00000803e";
/// dense segment (density 0.6 ≥ 0.5): tag 1 | lo=10 | hi=20 | 10 × f32
const SEG_DENSE: &str =
    "010a140000803f000000400000404000000000000080400000a0400000000000000000000000000000c040";
/// empty sparse segment over [0, 10)
const SEG_EMPTY: &str = "00000a000000";
/// container raw|raw, d=1000, 3 values, no perm, CRC-32 tail
const CONTAINER_PLAIN: &str =
    "4452310ae8070303726177037261770c070000002c010000e70300000c0000003f0000a0bf0000404000403690db";
/// container raw|raw with perm [2,0,1] bit-packed at 2 bits/entry
const CONTAINER_PERM: &str =
    "4452310a100303726177037261770c0200000005000000090000000c0000803f0000004000004040010201122c25272a";
/// chained container (v2 wire): magic "DR2\n" | version 2 | d=16 | 3
/// values | index spec "raw+deflate" | value spec "raw" | index bytes =
/// LZSS(raw u32 keys [2,5,9]) (literal-only stream: varint 12, tag 0,
/// varint 12, 12 bytes) | 3 × f32 LE | no perm | CRC-32
const CONTAINER_CHAIN: &str =
    "4452320a0210030b7261772b6465666c617465037261770f0c000c0200000005000000090000000c0000803f000000400000404000ea30f850";

#[test]
fn sparse_segment_bytes_are_stable() {
    let codec = SegmentCodec::raw(0.5);
    let t = st(100, &[(20, 1.5), (25, -2.0), (39, 0.25)]);
    let bytes = codec.encode(&t, 20, 40);
    assert_eq!(bytes, unhex(SEG_SPARSE), "sparse segment wire drift");
    // and the fixture decodes back to the tensor
    assert_eq!(codec.decode(100, &unhex(SEG_SPARSE)).unwrap(), t);
}

#[test]
fn dense_segment_bytes_are_stable() {
    let codec = SegmentCodec::raw(0.5);
    let t = st(50, &[(10, 1.0), (11, 2.0), (12, 3.0), (14, 4.0), (15, 5.0), (19, 6.0)]);
    let bytes = codec.encode(&t, 10, 20);
    assert_eq!(bytes, unhex(SEG_DENSE), "dense segment wire drift");
    assert_eq!(codec.decode(50, &unhex(SEG_DENSE)).unwrap(), t);
}

#[test]
fn empty_segment_bytes_are_stable() {
    let codec = SegmentCodec::raw(0.5);
    let t = st(10, &[]);
    assert_eq!(codec.encode(&t, 0, 10), unhex(SEG_EMPTY), "empty segment wire drift");
    let back = codec.decode(10, &unhex(SEG_EMPTY)).unwrap();
    assert_eq!(back.nnz(), 0);
    assert_eq!(back.dense_len(), 10);
}

#[test]
fn container_bytes_are_stable() {
    let c = Container::pack(
        1000,
        3,
        "raw",
        "raw",
        &[7u32, 300, 999].iter().flat_map(|i| i.to_le_bytes()).collect::<Vec<u8>>(),
        &[0.5f32, -1.25, 3.0].iter().flat_map(|v| v.to_le_bytes()).collect::<Vec<u8>>(),
        None,
    );
    assert_eq!(c.to_bytes(), unhex(CONTAINER_PLAIN), "container wire drift");
    // fixture parses with intact checksum and fields
    let parsed = Container::from_bytes(&unhex(CONTAINER_PLAIN)).unwrap();
    assert_eq!(parsed.dense_len, 1000);
    assert_eq!(parsed.num_values, 3);
    assert_eq!(parsed.index_codec, "raw");
    assert_eq!(parsed.value_codec, "raw");
    assert_eq!(parsed.perm, None);
}

#[test]
fn container_with_perm_bytes_are_stable() {
    let c = Container::pack(
        16,
        3,
        "raw",
        "raw",
        &[2u32, 5, 9].iter().flat_map(|i| i.to_le_bytes()).collect::<Vec<u8>>(),
        &[1.0f32, 2.0, 3.0].iter().flat_map(|v| v.to_le_bytes()).collect::<Vec<u8>>(),
        Some(&[2, 0, 1]),
    );
    assert_eq!(c.to_bytes(), unhex(CONTAINER_PERM), "perm container wire drift");
    let parsed = Container::from_bytes(&unhex(CONTAINER_PERM)).unwrap();
    assert_eq!(parsed.perm, Some(vec![2, 0, 1]));
}

#[test]
fn chained_container_bytes_are_stable() {
    // the v2 self-describing wire for a composed pipeline: the header
    // carries the full chain spec, the index payload is the head
    // codec's bytes pushed through the deflate stage
    let dr = deepreduce::compress::DeepReduce::builder()
        .index("raw+deflate")
        .value("raw")
        .build()
        .unwrap();
    let t = st(16, &[(2, 1.0), (5, 2.0), (9, 3.0)]);
    let c = dr.encode(&t, None);
    assert_eq!(c.to_bytes(), unhex(CONTAINER_CHAIN), "chained container wire drift");
    // fixture parses; the header names the chain; decoding through a
    // header-derived codec reproduces the tensor (self-description)
    let parsed = Container::from_bytes(&unhex(CONTAINER_CHAIN)).unwrap();
    assert_eq!(parsed.index_codec, "raw+deflate");
    assert_eq!(parsed.value_codec, "raw");
    let from_header = deepreduce::compress::DeepReduce::for_container(&parsed, 0).unwrap();
    assert_eq!(from_header.decode(&parsed).unwrap(), t);
}

#[test]
fn golden_fixtures_reject_any_single_byte_corruption() {
    // every byte of the container fixtures is load-bearing: flipping
    // any one must fail the CRC (or an earlier structural check)
    for fixture in [CONTAINER_PLAIN, CONTAINER_CHAIN] {
        let ok = unhex(fixture);
        for pos in 0..ok.len() {
            let mut bad = ok.clone();
            bad[pos] ^= 0x01;
            assert!(
                Container::from_bytes(&bad).is_err(),
                "corruption at byte {pos} went undetected"
            );
        }
    }
}

#[test]
fn every_fixture_prefix_is_rejected() {
    // truncated wire (any prefix length) must parse to a structured
    // error — no prefix is a valid container and nothing panics
    for fixture in [CONTAINER_PLAIN, CONTAINER_PERM, CONTAINER_CHAIN] {
        let ok = unhex(fixture);
        for len in 0..ok.len() {
            assert!(
                Container::from_bytes(&ok[..len]).is_err(),
                "prefix of {len} bytes parsed as a container"
            );
        }
    }
}
