//! Virtual-time fabric validation (DESIGN.md §9):
//!
//! 1. **Differential equivalence** — with zero latency and infinite
//!    bandwidth the event fabric must produce *byte-identical*
//!    allreduce results to the instant fabric for every schedule: the
//!    virtual clocks are pure bookkeeping and may never perturb the
//!    data path.
//! 2. **Cross-validation against the closed forms** — on homogeneous,
//!    no-jitter links with the uniform strided load the α–β models
//!    assume, the *measured* virtual critical path must agree with the
//!    `simnet` per-schedule formulas within ±10% (it lands well under
//!    1% — the slack covers wire-header vs model-header differences).
//! 3. **Trainer integration** (artifact-gated) — `--fabric virtual`
//!    must leave training results identical to the instant fabric
//!    while reporting non-zero `measured_step_s` / `rank_idle_s`.

use deepreduce::collective::{Network, Schedule, SparseConfig, Topology};
use deepreduce::simnet::{flat_schedule_time, hierarchical_time, Link, SegWire};
use deepreduce::tensor::SparseTensor;
use deepreduce::util::prng::Rng;
use deepreduce::util::testkit::sorted_support;
use deepreduce::vfabric::{Scenario, VirtualNetwork};
use std::thread;

/// Random sparse inputs (distinct support + Gaussian values per rank).
fn random_inputs(n: usize, d: usize, k: usize, seed: u64) -> Vec<SparseTensor> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            let support = sorted_support(&mut rng, d, k);
            let values: Vec<f32> = (0..k).map(|_| rng.next_gaussian() as f32).collect();
            SparseTensor::new(d, support, values)
        })
        .collect()
}

/// n disjoint, evenly-strided supports of k entries over [0, d) — the
/// uniform-load worst case the closed-form byte models assume exactly
/// (mirrors `simnet::tests::strided_inputs`).
fn strided_inputs(n: usize, d: usize, k: usize) -> Vec<SparseTensor> {
    let m = d / k;
    (0..n)
        .map(|r| {
            let off = r * m / n;
            let idx: Vec<u32> = (0..k).map(|j| (j * m + off) as u32).collect();
            let val: Vec<f32> = (0..k).map(|j| 0.5 + ((r * k + j) % 97) as f32 / 100.0).collect();
            SparseTensor::new(d, idx, val)
        })
        .collect()
}

fn run_instant(
    sched: Schedule,
    cfg: SparseConfig,
    topo: Topology,
    inputs: &[SparseTensor],
) -> Vec<SparseTensor> {
    let net = Network::with_topology(topo);
    let handles: Vec<_> = net
        .endpoints()
        .into_iter()
        .zip(inputs.to_vec())
        .map(|(ep, t)| thread::spawn(move || sched.build(cfg).allreduce(&ep, t).unwrap()))
        .collect();
    handles.into_iter().map(|h| h.join().unwrap()).collect()
}

/// Returns per-rank results plus the measured virtual critical path.
fn run_virtual(
    sched: Schedule,
    cfg: SparseConfig,
    topo: Topology,
    intra: Link,
    inter: Link,
    inputs: &[SparseTensor],
) -> (Vec<SparseTensor>, f64) {
    let net = VirtualNetwork::new(topo, intra, inter, Scenario::none(0));
    let handles: Vec<_> = net
        .endpoints()
        .into_iter()
        .zip(inputs.to_vec())
        .map(|(ep, t)| thread::spawn(move || sched.build(cfg).allreduce(&ep, t).unwrap()))
        .collect();
    let outs = handles.into_iter().map(|h| h.join().unwrap()).collect();
    (outs, net.max_clock_s())
}

/// (1) zero-latency / infinite-bandwidth event fabric ≡ instant fabric,
/// byte-identical per rank, for every schedule × world × seed.
#[test]
fn ideal_virtual_fabric_matches_instant_fabric_exactly() {
    let d = 4096usize;
    for &n in &[2usize, 3, 4, 8] {
        let topo = Topology::flat(n);
        for &seed in &[1u64, 2] {
            let inputs = random_inputs(n, d, d / 50, seed);
            for sched in Schedule::flat() {
                let cfg = SparseConfig { topology: Some(topo), ..SparseConfig::default() };
                let instant = run_instant(sched, cfg, topo, &inputs);
                let (virt, t) =
                    run_virtual(sched, cfg, topo, Link::ideal(), Link::ideal(), &inputs);
                assert_eq!(t, 0.0, "{sched:?} n={n}: ideal links must take zero virtual time");
                for (rank, (a, b)) in instant.iter().zip(&virt).enumerate() {
                    assert_eq!(
                        a.indices(),
                        b.indices(),
                        "{sched:?} n={n} seed={seed} rank={rank}: support differs"
                    );
                    // bit-exact: same merge order on both fabrics
                    let av: Vec<u32> = a.values().iter().map(|v| v.to_bits()).collect();
                    let bv: Vec<u32> = b.values().iter().map(|v| v.to_bits()).collect();
                    assert_eq!(av, bv, "{sched:?} n={n} seed={seed} rank={rank}: values differ");
                }
            }
        }
    }
}

/// (1b) same equivalence for the hierarchical schedule over real grids.
#[test]
fn ideal_virtual_fabric_matches_instant_hierarchical() {
    let d = 4096usize;
    for &(nodes, rpn) in &[(2usize, 2usize), (2, 4), (4, 2), (3, 3)] {
        let topo = Topology::new(nodes, rpn);
        let inputs = random_inputs(topo.world(), d, d / 50, 9);
        for inner in [Schedule::GatherAll, Schedule::RingRescatterExact] {
            let cfg = SparseConfig { topology: Some(topo), inner, ..SparseConfig::default() };
            let instant = run_instant(Schedule::Hierarchical, cfg, topo, &inputs);
            let (virt, _) = run_virtual(
                Schedule::Hierarchical,
                cfg,
                topo,
                Link::ideal(),
                Link::ideal(),
                &inputs,
            );
            for (rank, (a, b)) in instant.iter().zip(&virt).enumerate() {
                assert_eq!(a, b, "{}x{rpn} inner {inner:?} rank {rank}", topo.nodes);
            }
        }
    }
}

/// (2) homogeneous no-jitter links: measured virtual step time agrees
/// with the per-schedule closed forms within ±10% for every flat
/// schedule.
#[test]
fn measured_times_match_closed_forms_for_flat_schedules() {
    let d = 8192usize;
    let k = 1024usize;
    let w = SegWire::raw(0.5);
    let link = Link::mbps(100.0);
    for &n in &[4usize, 8] {
        let topo = Topology::flat(n);
        let inputs = strided_inputs(n, d, k);
        for sched in Schedule::flat() {
            let cfg = SparseConfig { topology: Some(topo), ..SparseConfig::default() };
            let (_, measured) = run_virtual(sched, cfg, topo, link, link, &inputs);
            let model = flat_schedule_time(sched, k as u64, d as u64, n, link, w, true);
            let err = (measured - model).abs() / model;
            assert!(
                err < 0.10,
                "{sched:?} n={n}: measured {measured:.6}s vs model {model:.6}s (err {err:.3})"
            );
        }
    }
}

/// (2b) same cross-validation for the hierarchical schedule with two
/// link classes (fast intra, slow inter).
#[test]
fn measured_time_matches_closed_form_for_hierarchical() {
    let d = 8192usize;
    let k = 512usize;
    let w = SegWire::raw(0.5);
    let intra = Link::gbps(10.0);
    let inter = Link::mbps(100.0);
    for &(nodes, rpn) in &[(2usize, 4usize), (4, 2)] {
        let topo = Topology::new(nodes, rpn);
        let inputs = strided_inputs(topo.world(), d, k);
        let cfg = SparseConfig { topology: Some(topo), ..SparseConfig::default() };
        let (_, measured) = run_virtual(Schedule::Hierarchical, cfg, topo, intra, inter, &inputs);
        let model = hierarchical_time(
            k as u64,
            d as u64,
            topo,
            intra,
            inter,
            w,
            Schedule::GatherAll,
            true,
        );
        let err = (measured - model).abs() / model;
        assert!(
            err < 0.10,
            "{}x{rpn}: measured {measured:.6}s vs model {model:.6}s (err {err:.3})",
            topo.nodes
        );
    }
}

/// Scenarios move measured time in the right direction: a straggler
/// stretches the critical path and shows up as other ranks' idle time.
#[test]
fn straggler_stretches_critical_path_and_idle() {
    let d = 8192usize;
    let n = 4usize;
    let topo = Topology::flat(n);
    let link = Link::mbps(100.0);
    let inputs = strided_inputs(n, d, 512);
    let cfg = SparseConfig { topology: Some(topo), ..SparseConfig::default() };
    let run = |scenario: Scenario| {
        let net = VirtualNetwork::new(topo, link, link, scenario);
        let handles: Vec<_> = net
            .endpoints()
            .into_iter()
            .zip(inputs.to_vec())
            .map(|(ep, t)| {
                thread::spawn(move || {
                    Schedule::GatherAll.build(cfg).allreduce(&ep, t).unwrap()
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        (net.max_clock_s(), net.total_idle_s())
    };
    let (base_t, base_idle) = run(Scenario::none(3));
    let (slow_t, slow_idle) = run(Scenario {
        stragglers: vec![(0, 8.0)],
        seed: 3,
        ..Scenario::default()
    });
    assert!(slow_t > base_t * 2.0, "straggler must stretch: {base_t} -> {slow_t}");
    assert!(slow_idle > base_idle, "peers must wait on the straggler");
}

// ---- trainer integration (artifact-gated, mirrors integration.rs) ----

use deepreduce::coordinator::{CompressionSpec, ModelKind, TrainConfig, Trainer};
use deepreduce::runtime::artifact_available;

fn mlp_cfg(fabric: &str, straggler: &str) -> TrainConfig {
    let mut spec = CompressionSpec::topk(0.05, "raw", f64::NAN, "raw", f64::NAN);
    spec.schedule = "ring_rescatter_exact".into();
    spec.fabric = fabric.into();
    spec.straggler = straggler.into();
    // compress every tensor so the collective (and thus the virtual
    // clock) is guaranteed to run
    spec.min_compress = 1;
    let mut cfg = TrainConfig::new(ModelKind::Mlp, "mlp");
    cfg.workers = 4;
    cfg.steps = 3;
    cfg.compression = Some(spec);
    cfg
}

/// (3) `--fabric virtual` changes the timing report, not the training:
/// losses match the instant fabric bit-for-bit and the measured fields
/// are populated.
#[test]
fn trainer_on_virtual_fabric_matches_instant_and_measures_time() {
    if !artifact_available("mlp") {
        eprintln!("SKIP: artifact mlp missing (run `make artifacts`)");
        return;
    }
    let ri = Trainer::new(mlp_cfg("instant", "")).unwrap().run().unwrap();
    let rv = Trainer::new(mlp_cfg("virtual", "")).unwrap().run().unwrap();
    assert_eq!(ri.steps.len(), rv.steps.len());
    for (a, b) in ri.steps.iter().zip(&rv.steps) {
        assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "fabric must not change the math");
        assert_eq!(a.fabric_bytes, b.fabric_bytes, "same schedule, same wire traffic");
        assert_eq!(a.measured_step_s, 0.0, "instant fabric has no virtual clock");
        assert!(a.rank_idle_s.is_none(), "instant fabric does not measure idleness");
        assert!(b.measured_step_s > 0.0, "virtual fabric must measure step time");
        assert!(b.rank_idle_s.unwrap() >= 0.0);
    }
    assert!(rv.total_measured_s() > 0.0);
}

/// A straggler scenario slows the measured clock but never the math.
#[test]
fn trainer_straggler_scenario_inflates_measured_time_only() {
    if !artifact_available("mlp") {
        eprintln!("SKIP: artifact mlp missing (run `make artifacts`)");
        return;
    }
    let base = Trainer::new(mlp_cfg("virtual", "")).unwrap().run().unwrap();
    let slow = Trainer::new(mlp_cfg("virtual", "0:16")).unwrap().run().unwrap();
    for (a, b) in base.steps.iter().zip(&slow.steps) {
        assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "scenario must not change the math");
    }
    assert!(
        slow.total_measured_s() > base.total_measured_s(),
        "straggler must inflate measured time: {} vs {}",
        slow.total_measured_s(),
        base.total_measured_s()
    );
    assert!(
        slow.total_rank_idle_s() > base.total_rank_idle_s(),
        "straggler must inflate peer idle time"
    );
}
