//! Fleet telemetry validation (DESIGN.md §14):
//!
//! 1. **Fabric-differential aggregation** — the per-step fleet health
//!    snapshot (class histograms, percentiles, detector flags) is
//!    **bit-identical** between the threaded virtual fabric and the
//!    fleet event-loop runner on every `scenario_corpus` entry. The
//!    telemetry is derived from per-rank virtual clocks, which the
//!    equivalence suite pins bit-exact below the barrage gate, so any
//!    divergence here is an aggregation bug, not fabric noise.
//! 2. **Detector exactness** — the MAD-based straggler detector flags
//!    exactly the injected `--straggler R:F` ranks on the corpus, with
//!    zero false positives on the uniform-compute entries, and every
//!    flag is scenario-confirmed (`expected == true`).

use deepreduce::collective::sparse::SegmentCodec;
use deepreduce::collective::{Schedule, SparseConfig, Topology};
use deepreduce::fleetsim::FleetFabric;
use deepreduce::obs::{FleetTelemetry, Lane, Span, SpanKind};
use deepreduce::simnet::Link;
use deepreduce::tensor::SparseTensor;
use deepreduce::util::testkit::scenario_corpus;
use deepreduce::vfabric::{Scenario, VirtualNetwork};
use std::thread;

/// Per-rank modelled forward/backward time before the exchange.
const BASE_COMPUTE: f64 = 2e-3;

/// Disjoint strided supports so merges are non-trivial on every rank.
fn inputs(n: usize, d: usize, k: usize) -> Vec<SparseTensor> {
    (0..n)
        .map(|r| {
            let idx: Vec<u32> = (0..k).map(|j| ((j * n + r) % d) as u32).collect();
            let val: Vec<f32> = (0..k).map(|j| 1.0 + (r * k + j) as f32 / 8.0).collect();
            SparseTensor::new(d, idx, val)
        })
        .collect()
}

fn vspan(kind: SpanKind, rank: usize, v0: f64, v1: f64) -> Span {
    Span {
        kind,
        lane: Lane::Cpu,
        rank: rank as u32,
        step: 0,
        depth: 0,
        bytes: 0,
        label: None,
        wall0: f64::NAN,
        wall1: f64::NAN,
        virt0: v0,
        virt1: v1,
    }
}

/// Per-rank clock marks of one step: (compute start, compute end,
/// exchange end) — the three instants both fabrics expose identically.
type Marks = Vec<(f64, f64, f64)>;

/// Compute replay + allreduce on the threaded virtual fabric.
fn threaded_marks(
    sched: Schedule,
    cfg: SparseConfig,
    topo: Topology,
    link: Link,
    scenario: &Scenario,
    ins: &[SparseTensor],
) -> Marks {
    let net = VirtualNetwork::new(topo, link, link, scenario.clone());
    let handles: Vec<_> = net
        .endpoints()
        .into_iter()
        .zip(ins.to_vec())
        .enumerate()
        .map(|(r, (ep, t))| {
            let factor = scenario.compute_factor(r, 0);
            thread::spawn(move || {
                ep.sync_to(0.0);
                let c0 = ep.now();
                ep.elapse(BASE_COMPUTE * factor);
                let c1 = ep.now();
                sched.build(cfg).allreduce(&ep, t).unwrap();
                (c0, c1, ep.now())
            })
        })
        .collect();
    handles.into_iter().map(|h| h.join().unwrap()).collect()
}

/// The same step on the fleet event-loop runner.
fn fleet_marks(
    sched: Schedule,
    cfg: SparseConfig,
    topo: Topology,
    link: Link,
    scenario: &Scenario,
    ins: &[SparseTensor],
) -> Marks {
    let mut fab = FleetFabric::new(topo, link, link, scenario.clone());
    let codec = SegmentCodec::raw(cfg.dense_switch);
    let n = fab.n();
    let mut marks: Marks = (0..n)
        .map(|r| {
            let c0 = fab.clock_s(r);
            fab.elapse(r, BASE_COMPUTE * scenario.compute_factor(r, 0));
            (c0, fab.clock_s(r), 0.0)
        })
        .collect();
    fab.allreduce(sched, &cfg, &codec, ins.to_vec()).unwrap();
    for (r, m) in marks.iter_mut().enumerate() {
        m.2 = fab.clock_s(r);
    }
    marks
}

/// Fold the step anatomy the marks describe (Compute/Exchange/Barrier
/// per rank, exactly what the fleet trainer path synthesizes) and
/// freeze the step.
fn telemetry_of(marks: &Marks, scenario: &Scenario) -> FleetTelemetry {
    let end = marks.iter().map(|m| m.2).fold(0.0, f64::max);
    let mut t = FleetTelemetry::new(marks.len());
    for (r, &(c0, c1, e)) in marks.iter().enumerate() {
        t.fold(&vspan(SpanKind::Compute, r, c0, c1));
        t.fold(&vspan(SpanKind::Exchange, r, c1, e));
        t.fold(&vspan(SpanKind::Barrier, r, e, end));
    }
    t.end_step(0, end, (0.0, end), Some(scenario));
    t
}

/// (1) fold the identical step anatomy from both fabrics' clocks and
/// require the frozen `StepHealth` JSON — histograms, percentiles,
/// sums, detector flags — to match bit-for-bit.
#[test]
fn fleet_and_threaded_fabrics_aggregate_bit_identically() {
    let n = 8usize;
    let d = 2048usize;
    let topo = Topology::new(2, 4);
    let link = Link::mbps(100.0);
    let ins = inputs(n, d, d / 40);
    for (si, scenario) in scenario_corpus(0xF1EE7, n).into_iter().enumerate() {
        for sched in [Schedule::GatherAll, Schedule::ChunkedRescatter] {
            let cfg = SparseConfig {
                topology: Some(topo),
                chunks: if sched == Schedule::ChunkedRescatter { 2 * n } else { 0 },
                ..SparseConfig::default()
            };
            let tm = threaded_marks(sched, cfg, topo, link, &scenario, &ins);
            let fm = fleet_marks(sched, cfg, topo, link, &scenario, &ins);
            let tj = telemetry_of(&tm, &scenario).steps()[0].to_json().to_string();
            let fj = telemetry_of(&fm, &scenario).steps()[0].to_json().to_string();
            assert_eq!(
                tj, fj,
                "scenario#{si} {sched:?}: fleet/threaded step-health JSON diverged"
            );
        }
    }
}

/// (2) the detector recovers exactly the injected straggler set per
/// corpus entry — `{}`, `{0, 4}` (0:2.0, 4:1.5), `{}`, `{}`, `{}`,
/// `{7}` (7:1.7) — with every flag scenario-confirmed. Compute factors
/// are deterministic on the corpus (no compute jitter), so these sets
/// are exact, not statistical.
#[test]
fn detector_recovers_injected_stragglers_with_zero_false_positives() {
    let n = 8usize;
    let d = 2048usize;
    let topo = Topology::new(2, 4);
    let link = Link::mbps(100.0);
    let ins = inputs(n, d, d / 40);
    let cfg = SparseConfig { topology: Some(topo), ..SparseConfig::default() };
    let expected: [&[u32]; 6] = [&[], &[0, 4], &[], &[], &[], &[7]];
    let corpus = scenario_corpus(0xF1EE7, n);
    assert_eq!(corpus.len(), expected.len(), "corpus shape changed; update expectations");
    for (si, (scenario, want)) in corpus.into_iter().zip(expected).enumerate() {
        let marks = fleet_marks(Schedule::GatherAll, cfg, topo, link, &scenario, &ins);
        let telemetry = telemetry_of(&marks, &scenario);
        assert_eq!(
            telemetry.steps()[0].flagged, want,
            "scenario#{si}: compute-flagged ranks"
        );
        for f in telemetry.flags().iter().filter(|f| f.metric == "compute_s") {
            assert!(
                f.expected,
                "scenario#{si} rank {}: compute flag not scenario-confirmed ({})",
                f.rank, f.cause
            );
            assert!(
                f.cause.contains("straggler"),
                "scenario#{si} rank {}: cause should name the straggler ({})",
                f.rank, f.cause
            );
        }
    }
}
