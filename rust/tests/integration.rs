//! Integration tests over the full stack: artifacts (Pallas/JAX → HLO
//! text) loaded and executed through the rust PJRT runtime, wired into
//! the coordinator with the DeepReduce codecs.
//!
//! Requires `make artifacts`; tests skip (with a note) when missing.
//!
//! ## Triage (DESIGN.md §7)
//!
//! Every test here is artifact-gated (`require_artifact!`): on a
//! checkout without `make artifacts` (e.g. the offline CI image, which
//! has no Python/JAX) they all skip and `cargo test -q` stays green.
//! Per-test status with artifacts present:
//!
//! | test                                            | gating                     |
//! |-------------------------------------------------|----------------------------|
//! | `pallas_smoke_artifact_executes_through_pjrt`   | deterministic — always on  |
//! | `qsgd_kernel_artifact_matches_rust_codec_math`  | deterministic — always on  |
//! | `fitpoly_kernel_artifact_agrees_with_rust_polyfit` | deterministic — always on |
//! | `mlp_distributed_training_with_bloom_p2_converges` | convergence threshold is statistical: strict form behind `DEEPREDUCE_STRICT_QUALITY=1`, structural checks always on |
//! | `compressed_matches_baseline_quality_on_short_run` | same gate — short-run loss ratios vary with BLAS/thread scheduling |
//! | `ncf_inherent_sparsity_observed_in_real_gradients` | deterministic — always on |
//! | `end_to_end_container_flow_over_real_gradients` | deterministic — always on  |
//!
//! The two quality tests were the flaky seed tests: their pass/fail
//! hinged on loss thresholds after 60–80 synthetic steps, which is
//! environment-sensitive. They now always verify the pipeline is sound
//! (finite losses, loss decreased, volume budget) and only enforce the
//! tight paper-shaped thresholds under `DEEPREDUCE_STRICT_QUALITY=1`
//! (set in nightly/quality CI, not the default matrix).

/// Strict statistical thresholds are opt-in: see the triage table above.
fn strict_quality() -> bool {
    std::env::var("DEEPREDUCE_STRICT_QUALITY").is_ok_and(|v| v == "1")
}

use deepreduce::compress::{index_by_name, value_by_name, DeepReduce};
use deepreduce::coordinator::{CompressionSpec, ModelKind, TrainConfig, Trainer};
use deepreduce::runtime::{artifact_available, Artifact, BatchInput};
use deepreduce::sparsify::Sparsifier;
use deepreduce::util::prng::Rng;

macro_rules! require_artifact {
    ($name:expr) => {
        if !artifact_available($name) {
            eprintln!("SKIP: artifact {} missing (run `make artifacts`)", $name);
            return;
        }
    };
}

#[test]
fn pallas_smoke_artifact_executes_through_pjrt() {
    require_artifact!("pallas_smoke");
    let art = Artifact::load_default("pallas_smoke").unwrap();
    let params = art.init_params(1);
    let mut data = deepreduce::data::SynthImages::new(64, 8, 16, 7);
    let out = art.train_step(&params, &data.next_batch()).unwrap();
    assert!(out.loss.is_finite());
    // random 8-way init: loss near ln(8)
    assert!((out.loss - (8f32).ln()).abs() < 1.5, "loss {}", out.loss);
    assert_eq!(out.grads.len(), params.len());
    for (g, p) in out.grads.iter().zip(&params) {
        assert_eq!(g.shape(), p.shape());
        assert!(g.data().iter().all(|v| v.is_finite()));
    }
    // determinism: same inputs -> identical outputs
    let mut data2 = deepreduce::data::SynthImages::new(64, 8, 16, 7);
    let out2 = art.train_step(&params, &data2.next_batch()).unwrap();
    assert_eq!(out.loss, out2.loss);
}

#[test]
fn qsgd_kernel_artifact_matches_rust_codec_math() {
    require_artifact!("qsgd");
    let art = Artifact::load_default("qsgd").unwrap();
    let n = art.manifest.config_usize("n").unwrap();
    let bucket = art.manifest.config_usize("bucket").unwrap();
    let bits = art.manifest.config_usize("bits").unwrap() as u32;
    let mut rng = Rng::new(9);
    let values: Vec<f32> = (0..n).map(|_| rng.next_gaussian() as f32).collect();
    let randoms: Vec<f32> = (0..n).map(|_| rng.next_f32()).collect();
    let outs = art
        .run_kernel(&[BatchInput::F32(values.clone()), BatchInput::F32(randoms.clone())])
        .unwrap();
    let (levels, signs, maxs) = (&outs[0], &outs[1], &outs[2]);
    // replicate the same math in rust
    let s = ((1u32 << bits) - 1) as f32;
    for b in 0..n / bucket {
        let chunk = &values[b * bucket..(b + 1) * bucket];
        let mx = chunk.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
        assert!((maxs[b] - mx).abs() <= mx * 1e-6, "bucket {b}");
        for j in 0..bucket {
            let i = b * bucket + j;
            let expected = if mx > 0.0 {
                ((values[i].abs() / mx * s + randoms[i]).floor()).min(s)
            } else {
                0.0
            };
            assert_eq!(levels[i], expected, "level at {i}");
            assert_eq!(signs[i], if values[i] < 0.0 { -1.0 } else { 1.0 });
        }
    }
}

#[test]
fn fitpoly_kernel_artifact_agrees_with_rust_polyfit() {
    require_artifact!("fitpoly");
    let art = Artifact::load_default("fitpoly").unwrap();
    let segs = art.manifest.config_usize("segs").unwrap();
    let seg_len = art.manifest.config_usize("seg_len").unwrap();
    let degree = art.manifest.config_usize("degree").unwrap();
    // one smooth sorted-curve per segment
    let mut rng = Rng::new(11);
    let mut y = vec![0.0f32; segs * seg_len];
    let mut mask = vec![0.0f32; segs * seg_len];
    let mut x0 = vec![0.0f32; segs];
    let mut lens = vec![0usize; segs];
    for sgi in 0..segs {
        let len = (degree + 2) + rng.below((seg_len - degree - 2) as u64) as usize;
        lens[sgi] = len;
        x0[sgi] = (sgi * seg_len) as f32;
        for j in 0..len {
            let t = j as f64 / len as f64;
            y[sgi * seg_len + j] = (2.0 * (-3.0 * t).exp() + 0.1 * t) as f32;
            mask[sgi * seg_len + j] = 1.0;
        }
    }
    let outs = art
        .run_kernel(&[BatchInput::F32(y.clone()), BatchInput::F32(mask), BatchInput::F32(x0.clone())])
        .unwrap();
    let coeffs = &outs[0]; // [segs, degree+1]
    let m = degree + 1;
    for sgi in 0..segs {
        let seg_y: Vec<f64> =
            (0..lens[sgi]).map(|j| y[sgi * seg_len + j] as f64).collect();
        let rust_fit =
            deepreduce::linalg::polyfit(x0[sgi] as usize, &seg_y, degree).unwrap();
        // compare reconstructions (coefficient bases may differ slightly by conditioning)
        for j in 0..lens[sgi] {
            let t = if lens[sgi] > 1 {
                // kernel domain: mid/half over the segment
                let x1 = x0[sgi] as f64 + (lens[sgi] - 1) as f64;
                let mid = (x0[sgi] as f64 + x1) / 2.0;
                let half = ((x1 - x0[sgi] as f64) / 2.0).max(1.0);
                ((x0[sgi] as f64 + j as f64) - mid) / half
            } else {
                0.0
            };
            let mut kernel_val = 0.0f64;
            for p in (0..m).rev() {
                kernel_val = kernel_val * t + coeffs[sgi * m + p] as f64;
            }
            let rust_val = rust_fit.eval((x0[sgi] as usize + j) as f64) as f64;
            assert!(
                (kernel_val - rust_val).abs() < 1e-2 * (1.0 + rust_val.abs()),
                "seg {sgi} j {j}: kernel {kernel_val} vs rust {rust_val}"
            );
        }
    }
}

#[test]
fn mlp_distributed_training_with_bloom_p2_converges() {
    require_artifact!("mlp");
    let mut cfg = TrainConfig::new(ModelKind::Mlp, "mlp");
    cfg.workers = 2;
    cfg.steps = 60;
    cfg.compression =
        Some(CompressionSpec::topk(0.01, "bloom_p2", 0.001, "raw", f64::NAN));
    let mut t = Trainer::new(cfg).unwrap();
    let report = t.run().unwrap();
    let first = report.steps[0].loss;
    let last = report.final_loss();
    // structural soundness: finite and non-increasing loss trend
    assert!(first.is_finite() && last.is_finite(), "non-finite losses: {first} -> {last}");
    assert!(last < first, "loss did not decrease at all: {first} -> {last}");
    // volume: top-1% + bloom index must be way below dense (deterministic)
    assert!(report.relative_volume() < 0.05, "volume {}", report.relative_volume());
    if strict_quality() {
        assert!(last < first * 0.8, "no convergence: {first} -> {last}");
    } else {
        eprintln!("NOTE: lenient mode ({first:.4} -> {last:.4}); DEEPREDUCE_STRICT_QUALITY=1 enforces < 0.8x");
    }
}

#[test]
fn compressed_matches_baseline_quality_on_short_run() {
    require_artifact!("mlp");
    let run = |compression: Option<CompressionSpec>| {
        let mut cfg = TrainConfig::new(ModelKind::Mlp, "mlp");
        cfg.workers = 2;
        cfg.steps = 80;
        cfg.compression = compression;
        Trainer::new(cfg).unwrap().run().unwrap()
    };
    let baseline = run(None);
    let dr = run(Some(CompressionSpec::topk(0.05, "bloom_p0", 0.001, "raw", f64::NAN)));
    // structural soundness: both runs finish with finite losses
    assert!(baseline.final_loss().is_finite() && dr.final_loss().is_finite());
    if strict_quality() {
        // P0 is lossless in support; with EF the quality stays close
        assert!(
            dr.final_loss() < baseline.final_loss() * 1.35 + 0.1,
            "dr {} vs baseline {}",
            dr.final_loss(),
            baseline.final_loss()
        );
    } else {
        eprintln!(
            "NOTE: lenient mode (dr {:.4} vs baseline {:.4}); DEEPREDUCE_STRICT_QUALITY=1 enforces 1.35x",
            dr.final_loss(),
            baseline.final_loss()
        );
    }
}

#[test]
fn ncf_inherent_sparsity_observed_in_real_gradients() {
    require_artifact!("ncf");
    let art = Artifact::load_default("ncf").unwrap();
    let params = art.init_params(3);
    let mut data = deepreduce::data::SynthNcf::new(
        art.manifest.config_usize("users").unwrap(),
        art.manifest.config_usize("items").unwrap(),
        art.manifest.config_usize("batch").unwrap(),
        5,
    );
    let out = art.train_step(&params, &data.next_batch()).unwrap();
    // embedding gradients (params 0, 1) are inherently sparse (paper §1:
    // NCF grads ~40% zeros; here batch << table size so sparsity is high)
    for ti in 0..2 {
        let zeros = out.grads[ti].zero_count();
        let total = out.grads[ti].numel();
        assert!(
            zeros as f64 / total as f64 > 0.3,
            "grad {ti}: only {zeros}/{total} zeros"
        );
    }
}

#[test]
fn end_to_end_container_flow_over_real_gradients() {
    require_artifact!("mlp");
    let art = Artifact::load_default("mlp").unwrap();
    let params = art.init_params(4);
    let mut data = deepreduce::data::SynthImages::new(3072, 10, 128, 6);
    let out = art.train_step(&params, &data.next_batch()).unwrap();
    let grad = &out.grads[0]; // the 3072x80 weight
    let mut topk = deepreduce::sparsify::TopK::new(0.01);
    let sp = topk.sparsify(grad.data());
    // bitmap omitted from the volume assertion: at 1% sparsity the d-bit
    // string exceeds r·64-bit kv pairs (it wins above ~1/64 density —
    // exactly the Fig 1 trade-off)
    for (i, v) in [
        ("rle", "fp16"),
        ("huffman", "raw"),
        ("bloom_p0", "deflate"),
        ("bloom_p2", "fitpoly"),
        ("delta_varint", "qsgd"),
    ] {
        let dr = DeepReduce::new(
            index_by_name(i, 0.001, 3).unwrap(),
            value_by_name(v, f64::NAN, 3).unwrap(),
        );
        let container = dr.encode(&sp, Some(grad.data()));
        let bytes = container.to_bytes();
        let parsed = deepreduce::compress::Container::from_bytes(&bytes).unwrap();
        let decoded = dr.decode(&parsed).unwrap();
        assert_eq!(decoded.dense_len(), grad.numel(), "{i}/{v}");
        assert!(decoded.nnz() > 0);
        // wire volume below raw kv for every instantiation
        assert!(
            bytes.len() < sp.kv_wire_bytes() + 64,
            "{i}/{v}: {} vs kv {}",
            bytes.len(),
            sp.kv_wire_bytes()
        );
    }
}
