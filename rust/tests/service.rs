//! Integration tests for the multi-tenant reduction service
//! (`deepreduce::service`): daemon smoke, fair-share properties under an
//! adversarial tenant mix, `PROFILE_*.json` hardening, and per-job
//! artifact naming.

use deepreduce::collective::Topology;
use deepreduce::obs::{FleetTelemetry, Lane, Span, SpanKind, TraceLevel, TraceReport};
use deepreduce::pipeline::{default_candidates, CodecPolicy};
use deepreduce::service::{
    JobId, JobRequest, Profile, ProfileError, ReductionService, ServiceConfig,
};
use deepreduce::simnet::Link;
use deepreduce::util::benchkit::BenchSummary;
use deepreduce::util::json::Json;
use std::collections::BTreeMap;

/// Daemon smoke: two in-process jobs share one fabric, interleave under
/// the scheduler, meter their own traffic, and release capacity on
/// finish — the lifecycle the `serve` subcommand drives.
#[test]
fn daemon_smoke_interleaves_two_jobs_and_recycles_capacity() {
    let mut svc = ReductionService::new(ServiceConfig::new(
        Topology::new(2, 4),
        Link::mbps(1000.0),
        Link::mbps(100.0),
    ));
    let a = svc.submit(JobRequest::synthetic("jobA", 4, 1 << 12, 0.01)).expect("admit A");
    let b = svc.submit(JobRequest::synthetic("jobB", 4, 1 << 12, 0.05)).expect("admit B");
    assert_eq!(svc.free_ranks(), 0);
    let rounds = 3usize;
    for _ in 0..rounds {
        let reports = svc.run_round().expect("round");
        assert!(reports.iter().any(|r| r.job == a), "A missed a round");
        assert!(reports.iter().any(|r| r.job == b), "B missed a round");
    }
    for id in [a, b] {
        let job = svc.job(id).expect("queryable");
        assert!(job.steps >= rounds as u64, "{} made {} steps", job.name, job.steps);
        assert!(job.bytes[0] > 0, "{} metered no intra traffic", job.name);
        assert_eq!(job.bytes[1], 0, "{} spans one node, must not meter inter", job.name);
        assert!(job.virtual_s > 0.0);
    }
    svc.finish(a).expect("finish A");
    svc.finish(b).expect("finish B");
    assert_eq!(svc.free_ranks(), 8, "finished jobs release their ranks");
    // the freed capacity admits a new tenant — and the freed *name* too
    let a2 = svc.submit(JobRequest::synthetic("jobA", 8, 1 << 12, 0.01)).expect("readmit");
    assert_eq!(svc.job(a2).expect("queryable").placement.len(), 8);
}

/// Fair-share property test: one dense bully next to six sparse tenants
/// on a tight frame budget. Every tenant must progress every round (the
/// progress floor), the bully must never win surplus steps (its step
/// estimate exceeds its per-round credit), the sparse tenants must
/// collectively receive surplus, and the per-round scheduled estimate
/// must respect the round quota (frame budget + one burst per tenant).
#[test]
fn fair_share_bully_cannot_starve_sparse_tenants() {
    let topo = Topology::new(8, 2);
    let dim = 1usize << 12;
    let budget = [60_000.0, 60_000.0];
    let mut svc = ReductionService::new(
        ServiceConfig::new(topo, Link::mbps(1000.0), Link::mbps(100.0))
            .with_frame_budget(budget),
    );
    let dense_req = JobRequest::synthetic("dense", 2, dim, 0.3);
    let dense_est = dense_req.est_step_bytes();
    let sparse_est = JobRequest::synthetic("s", 2, dim, 0.01).est_step_bytes();
    // the mix must be adversarial: the bully's floor step alone outweighs
    // its fair credit share, and the whole mix still fits the frame
    assert!(dense_est > budget[0] / 7.0, "dense step must exceed its credit share");
    assert!(dense_est + 6.0 * sparse_est <= budget[0], "mix must pass admission");
    let dense = svc.submit(dense_req).expect("admit dense");
    let mut sparse: Vec<JobId> = Vec::new();
    for i in 0..6 {
        sparse.push(
            svc.submit(JobRequest::synthetic(&format!("s{i}"), 2, dim, 0.01))
                .expect("admit sparse"),
        );
    }
    let est_of = |id: JobId| if id == dense { dense_est } else { sparse_est };
    let quota = svc.shares().round_quota();
    let rounds = 12usize;
    for round in 0..rounds {
        let reports = svc.run_round().expect("round");
        let mut scheduled = 0.0;
        for r in &reports {
            scheduled += est_of(r.job);
            assert_eq!(r.bytes[1], 0, "single-node placements never meter inter");
        }
        assert!(
            scheduled <= quota[0] + 1e-6,
            "round {round} scheduled {scheduled:.0} B of estimate, quota {:.0} B",
            quota[0]
        );
        assert!(reports.iter().any(|r| r.job == dense), "dense missed round {round}");
        for id in &sparse {
            assert!(reports.iter().any(|r| r.job == *id), "{id} missed round {round}");
        }
    }
    // the bully got exactly its floor; the surplus went to the sparse mix
    assert_eq!(
        svc.job(dense).expect("dense").steps,
        rounds as u64,
        "a bully whose step exceeds its credit share never wins surplus"
    );
    let sparse_steps: u64 = sparse.iter().map(|id| svc.job(*id).expect("sparse").steps).sum();
    assert!(
        sparse_steps > (6 * rounds) as u64,
        "sparse tenants should win surplus steps beyond the floor: {sparse_steps}"
    );
}

/// Warm start across service instances: a cold autotuned job persists
/// its profile at finish; a second service submitting the same
/// (model, topology, link) key loads it instead of re-calibrating, and
/// pays measurably less setup ahead of its first step.
#[test]
fn warm_start_reuses_the_persisted_profile() {
    let dir = std::env::temp_dir().join(format!("svc-warm-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let cfg = || {
        ServiceConfig::new(Topology::new(2, 4), Link::mbps(1000.0), Link::mbps(100.0))
            .with_profiles(dir.clone())
    };
    let autotuned = |name: &str| JobRequest {
        model: "resnet-sim".to_string(),
        autotune: true,
        ..JobRequest::synthetic(name, 4, 1 << 12, 0.01)
    };
    let mut cold_svc = ReductionService::new(cfg());
    let cold_id = cold_svc.submit(autotuned("first")).expect("cold admit");
    cold_svc.step_job(cold_id).expect("cold step");
    let (cold_setup, cold_first) = {
        let job = cold_svc.job(cold_id).expect("cold job");
        assert!(!job.setup.warm_start, "empty store must cold-start");
        assert!(job.setup.calibration_s > 0.0, "cold start pays the calibration sweep");
        (job.setup.total_s(), job.first_step_s.expect("stepped"))
    };
    let path = cold_svc.finish(cold_id).expect("finish").expect("autotuned job persists");
    assert!(path.exists(), "profile file on disk");
    assert!(
        path.file_name().and_then(|f| f.to_str()).unwrap_or("").starts_with("PROFILE_"),
        "profile artifact naming: {path:?}"
    );

    let mut warm_svc = ReductionService::new(cfg());
    let warm_id = warm_svc.submit(autotuned("second")).expect("warm admit");
    warm_svc.step_job(warm_id).expect("warm step");
    {
        let job = warm_svc.job(warm_id).expect("warm job");
        assert!(job.setup.warm_start, "same key must warm-start");
        assert_eq!(job.setup.calibration_s, 0.0, "warm start skips the sweep");
        assert!(
            job.setup.total_s() < cold_setup,
            "warm setup {:.6}s not below cold {:.6}s",
            job.setup.total_s(),
            cold_setup
        );
        assert!(
            job.first_step_s.expect("stepped") < cold_first,
            "warm first step {:.6}s not below cold {:.6}s",
            job.first_step_s.expect("stepped"),
            cold_first
        );
    }
    warm_svc.finish(warm_id).expect("finish");
    let _ = std::fs::remove_dir_all(&dir);
}

fn golden_profile() -> Profile {
    let (idx, val) = default_candidates(false);
    let policy = CodecPolicy::calibrate_bytes_only(&idx, &val, 7, Link::mbps(100.0), 4);
    Profile {
        key: deepreduce::service::ProfileKey::new("golden", "2x4", Link::mbps(100.0)),
        policy: policy.export_json(),
        schedule: Some(("chunked_rescatter".to_string(), 4)),
    }
}

/// PROFILE hardening: the golden fixture round-trips byte-stable, every
/// strict prefix of it is rejected with a structured error (never a
/// panic), and field-level damage maps to the matching error variant.
#[test]
fn profile_golden_roundtrip_survives_truncation_and_corruption() {
    let golden = golden_profile();
    let bytes = golden.to_bytes();
    let back = Profile::from_bytes(&bytes).expect("golden fixture loads");
    assert_eq!(back.to_bytes(), bytes, "byte-stable round trip");
    assert_eq!(back.key, golden.key);
    assert_eq!(back.schedule, golden.schedule);

    // prefix-truncation sweep: a partially-written profile (crash during
    // save) must fail structurally at every cut point
    for cut in 0..bytes.len() {
        match Profile::from_bytes(&bytes[..cut]) {
            Err(_) => {}
            Ok(_) => panic!("truncated profile ({cut}/{} bytes) must not load", bytes.len()),
        }
    }

    // field-level corruption maps to the matching structured variant
    let mutate = |f: &dyn Fn(&mut BTreeMap<String, Json>)| -> Result<Profile, ProfileError> {
        let mut v = Json::parse(std::str::from_utf8(&bytes).unwrap()).unwrap();
        if let Json::Obj(m) = &mut v {
            f(m);
        }
        Profile::from_bytes(v.to_string().as_bytes())
    };
    assert!(matches!(
        mutate(&|m| {
            m.insert("schema_version".into(), Json::Num(99.0));
        }),
        Err(ProfileError::Schema { found: Some(99), expect: 1 })
    ));
    assert!(matches!(
        mutate(&|m| {
            m.remove("schema_version");
        }),
        Err(ProfileError::Schema { found: None, expect: 1 })
    ));
    assert!(matches!(
        mutate(&|m| {
            m.insert("kind".into(), Json::Str("deepreduce_bench".into()));
        }),
        Err(ProfileError::WrongKind { .. })
    ));
    assert!(matches!(
        mutate(&|m| {
            m.insert("policy".into(), Json::Null);
        }),
        Err(ProfileError::Malformed { .. })
    ));
    assert!(matches!(
        mutate(&|m| {
            m.remove("model");
        }),
        Err(ProfileError::Malformed { .. })
    ));
    assert!(matches!(
        mutate(&|m| {
            let mut s = BTreeMap::new();
            s.insert("schedule".to_string(), Json::Str("warp_drive".into()));
            s.insert("chunks".to_string(), Json::Num(4.0));
            m.insert("schedule".into(), Json::Obj(s));
        }),
        Err(ProfileError::Malformed { .. })
    ));
    assert!(matches!(Profile::from_bytes(&[0xFF, 0xFE, 0xFD]), Err(ProfileError::Utf8)));
}

fn vspan(rank: u32, v0: f64, v1: f64) -> Span {
    Span {
        kind: SpanKind::Compute,
        lane: Lane::Cpu,
        rank,
        step: 0,
        depth: 0,
        bytes: 0,
        label: None,
        wall0: f64::NAN,
        wall1: f64::NAN,
        virt0: v0,
        virt1: v1,
    }
}

/// Per-job artifact naming: `for_job` prefixes the BENCH/TRACE/HEALTH
/// stems so concurrent tenants never clobber each other's artifacts,
/// and the health report's exemplar-trace pointer follows the renamed
/// stem automatically.
#[test]
fn artifacts_are_prefixed_per_job() {
    let bench = BenchSummary::new("service_smoke").for_job("tenant0");
    let bj = bench.to_json();
    assert_eq!(bj.get("bench").and_then(Json::as_str), Some("tenant0_service_smoke"));
    assert_eq!(bj.get("job").and_then(Json::as_str), Some("tenant0"));

    let trace = TraceReport {
        name: "svc".to_string(),
        level: TraceLevel::Step,
        ranks: 2,
        meta: BTreeMap::new(),
        steps: Vec::new(),
        spans: vec![vspan(0, 0.0, 1.0)],
        registry: Json::Null,
    }
    .for_job("tenant1");
    assert_eq!(trace.name, "tenant1_svc");
    assert_eq!(trace.meta.get("job").and_then(Json::as_str), Some("tenant1"));

    let mut telemetry = FleetTelemetry::new(2);
    telemetry.fold(&vspan(0, 0.0, 1.0));
    telemetry.fold(&vspan(1, 0.0, 1.5));
    telemetry.end_step(0, 1.5, (0.0, 1.5), None);
    let health = telemetry.report("svc", BTreeMap::new()).for_job("tenant2");
    assert_eq!(health.name, "tenant2_svc");
    let hj = health.to_json();
    let pointer = hj
        .get("exemplar_trace")
        .and_then(|e| e.get("trace"))
        .and_then(Json::as_str)
        .expect("exemplar pointer");
    assert_eq!(
        pointer, "TRACE_tenant2_svc.json",
        "the exemplar pointer must follow the per-job stem"
    );
}
