//! Differential harness pinning the fleet event-loop runner
//! (`deepreduce::fleetsim`) to the threaded virtual-time fabric
//! (`deepreduce::vfabric`):
//!
//! 1. **Differential equivalence** — every schedule × input family
//!    (uniform, skewed, empty-rank) × scenario corpus entry at
//!    n ∈ {2, 4, 7, 8}: byte-identical results and per-link-class
//!    meters, virtual clocks and idle within ±1e-9 (they are bit-exact
//!    below the barrage gate; the tolerance is the ISSUE contract).
//! 2. **Determinism** — same seed ⇒ bit-identical BENCH/TRACE JSON
//!    across two runs *and* across ready-queue policies (FIFO, LIFO,
//!    seeded shuffles): all timing state is rank-local, so scheduling
//!    order cannot leak into any observable.
//! 3. **Golden jitter streams** — the per-rank jitter RNG construction
//!    (`seed ^ mix64(rank)`) both fabrics share, pinned to golden
//!    draws so a platform- or refactor-induced drift fails loudly.
//! 4. **Elastic membership** — crash windows exclude ranks from the
//!    sum without touching their clocks.
//! 5. **Scale tier** (`DEEPREDUCE_SCALE_TESTS=1`) — 1024-rank closed
//!    -form cross-validation and the hierarchical inter-byte win.

use deepreduce::collective::sparse::SegmentCodec;
use deepreduce::collective::{Schedule, SparseConfig, Topology};
use deepreduce::fleetsim::{FleetFabric, ReadyPolicy};
use deepreduce::obs::{self, StepWindow, TraceLevel, TraceReport, Tracer};
use deepreduce::simnet::{chunked_rescatter_bytes, Link, SegWire};
use deepreduce::tensor::SparseTensor;
use deepreduce::util::json::Json;
use deepreduce::util::prng::{mix64, Rng};
use deepreduce::util::testkit::{scenario_corpus, sorted_support};
use deepreduce::vfabric::{Scenario, VirtualNetwork};
use std::collections::BTreeMap;
use std::thread;

// ------------------------------------------------------------ inputs

#[derive(Clone, Copy, Debug)]
enum Family {
    /// equal nnz per rank, random disjoint-ish supports
    Uniform,
    /// nnz grows with rank (hot embedding rows, unbalanced shards)
    Skewed,
    /// one rank contributes nothing (a bucket with no survivors)
    EmptyRank,
}

const FAMILIES: [Family; 3] = [Family::Uniform, Family::Skewed, Family::EmptyRank];

fn inputs(family: Family, n: usize, d: usize, seed: u64) -> Vec<SparseTensor> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|r| {
            let k = match family {
                Family::Uniform => d / 40,
                Family::Skewed => 2 + (r * d) / (20 * n),
                Family::EmptyRank => {
                    if r == n / 2 {
                        0
                    } else {
                        d / 40
                    }
                }
            };
            let support = sorted_support(&mut rng, d, k);
            let values: Vec<f32> = (0..support.len())
                .map(|_| rng.next_gaussian() as f32)
                .collect();
            SparseTensor::new(d, support, values)
        })
        .collect()
}

// ------------------------------------------------------------ runners

struct RunOut {
    results: Vec<SparseTensor>,
    /// per-rank (virtual clock, recv-wait idle)
    clocks: Vec<(f64, f64)>,
    /// (total, intra, inter) fabric bytes
    bytes: (u64, u64, u64),
}

fn run_threaded(
    sched: Schedule,
    cfg: SparseConfig,
    topo: Topology,
    intra: Link,
    inter: Link,
    scenario: Scenario,
    inputs: &[SparseTensor],
) -> RunOut {
    let net = VirtualNetwork::new(topo, intra, inter, scenario);
    let handles: Vec<_> = net
        .endpoints()
        .into_iter()
        .zip(inputs.to_vec())
        .map(|(ep, t)| {
            thread::spawn(move || {
                let out = sched.build(cfg).allreduce(&ep, t).unwrap();
                (out, ep.now(), ep.idle_s())
            })
        })
        .collect();
    let mut results = Vec::new();
    let mut clocks = Vec::new();
    for h in handles {
        let (out, now, idle) = h.join().unwrap();
        results.push(out);
        clocks.push((now, idle));
    }
    RunOut { results, clocks, bytes: (net.total_bytes(), net.intra_bytes(), net.inter_bytes()) }
}

fn run_fleet(
    sched: Schedule,
    cfg: SparseConfig,
    topo: Topology,
    intra: Link,
    inter: Link,
    scenario: Scenario,
    inputs: &[SparseTensor],
    policy: ReadyPolicy,
) -> RunOut {
    let mut fab = FleetFabric::new(topo, intra, inter, scenario).with_policy(policy);
    let codec = SegmentCodec::raw(cfg.dense_switch);
    let results = fab.allreduce(sched, &cfg, &codec, inputs.to_vec()).unwrap();
    let n = fab.n();
    RunOut {
        results,
        clocks: (0..n).map(|r| (fab.clock_s(r), fab.idle_s(r))).collect(),
        bytes: (fab.total_bytes(), fab.intra_bytes(), fab.inter_bytes()),
    }
}

fn assert_equivalent(label: &str, threaded: &RunOut, fleet: &RunOut) {
    assert_eq!(
        threaded.bytes, fleet.bytes,
        "{label}: per-link-class byte meters must be identical"
    );
    for (rank, (a, b)) in threaded.results.iter().zip(&fleet.results).enumerate() {
        assert_eq!(a.indices(), b.indices(), "{label} rank={rank}: support differs");
        let av: Vec<u32> = a.values().iter().map(|v| v.to_bits()).collect();
        let bv: Vec<u32> = b.values().iter().map(|v| v.to_bits()).collect();
        assert_eq!(av, bv, "{label} rank={rank}: values differ (merge order leaked)");
    }
    for (rank, ((tc, ti), (fc, fi))) in threaded.clocks.iter().zip(&fleet.clocks).enumerate() {
        assert!(
            (tc - fc).abs() <= 1e-9,
            "{label} rank={rank}: clock diverged (threaded {tc} vs fleet {fc})"
        );
        assert!(
            (ti - fi).abs() <= 1e-9,
            "{label} rank={rank}: idle diverged (threaded {ti} vs fleet {fi})"
        );
    }
}

// ---------------------------------------------------- 1. differential

/// The tentpole contract: at every n ≤ 8 differential point the fleet
/// runner is indistinguishable from the threaded fabric — bytes exact,
/// clocks within 1e-9 — for every schedule, input family, and scenario
/// corpus entry, on both a flat world and (n even) a 2-node grid.
#[test]
fn fleet_runner_matches_threaded_fabric_at_all_differential_points() {
    let d = 2000usize;
    let intra = Link::gbps(10.0);
    let inter = Link::mbps(100.0);
    for &n in &[2usize, 4, 7, 8] {
        let mut grids = vec![Topology::flat(n)];
        if n % 2 == 0 {
            grids.push(Topology::new(2, n / 2));
        }
        for topo in grids {
            for family in FAMILIES {
                let ins = inputs(family, n, d, 0x5EED ^ n as u64);
                for sched in Schedule::all() {
                    let cfg = SparseConfig {
                        topology: Some(topo),
                        chunks: if sched == Schedule::ChunkedRescatter { 2 * n } else { 0 },
                        ..SparseConfig::default()
                    };
                    for (si, scenario) in scenario_corpus(0xF1EE7, n).into_iter().enumerate() {
                        let label = format!(
                            "{sched:?} n={n} topo={} family={family:?} scenario#{si}",
                            topo.label()
                        );
                        let threaded = run_threaded(
                            sched,
                            cfg,
                            topo,
                            intra,
                            inter,
                            scenario.clone(),
                            &ins,
                        );
                        let fleet = run_fleet(
                            sched,
                            cfg,
                            topo,
                            intra,
                            inter,
                            scenario,
                            &ins,
                            ReadyPolicy::Fifo,
                        );
                        assert_equivalent(&label, &threaded, &fleet);
                    }
                }
            }
        }
    }
}

// ---------------------------------------------------- 2. determinism

/// One fleet run's observable artifacts, serialized the way the CLI
/// writes them: a TRACE JSON (virtual spans only, canonically ordered —
/// span *content* is rank-local, so any poll order must produce the
/// same set) and a BENCH JSON fingerprint of meters and exact clock
/// bits.
fn fleet_fingerprint(policy: ReadyPolicy) -> (String, String) {
    let n = 8usize;
    let d = 2048usize;
    let topo = Topology::new(4, 2);
    let scenario = scenario_corpus(0xD373, n).pop().expect("corpus nonempty");
    let tracer = Tracer::new(TraceLevel::Full, n);
    let _bind = tracer.install(0);
    let mut fab =
        FleetFabric::new(topo, Link::gbps(2.0), Link::mbps(80.0), scenario).with_policy(policy);
    let codec = SegmentCodec::raw(0.5);
    for (i, sched) in
        [Schedule::ChunkedRescatter, Schedule::RingRescatter, Schedule::Hierarchical]
            .into_iter()
            .enumerate()
    {
        let cfg = SparseConfig { topology: Some(topo), ..SparseConfig::default() };
        let ins = inputs(Family::Skewed, n, d, 0xBEEF + i as u64);
        fab.allreduce(sched, &cfg, &codec, ins).unwrap();
    }
    obs::flush();
    let mut spans = tracer.drain(0);
    // wall-stamped spans are scheduling noise by definition (one OS
    // thread multiplexes every rank); the exported trace is virtual-only
    spans.retain(|s| !s.has_wall());
    spans.sort_by(|a, b| {
        a.rank
            .cmp(&b.rank)
            .then(a.virt0.total_cmp(&b.virt0))
            .then(a.virt1.total_cmp(&b.virt1))
            .then(a.lane.name().cmp(b.lane.name()))
            .then(a.kind.name().cmp(b.kind.name()))
            .then(a.bytes.cmp(&b.bytes))
    });
    let report = TraceReport {
        name: "fleetsim_determinism".to_string(),
        level: TraceLevel::Full,
        ranks: n,
        meta: BTreeMap::new(),
        steps: vec![StepWindow {
            step: 0,
            measured_s: fab.max_clock_s(),
            idle_mean_s: fab.total_idle_s() / n as f64,
            virt0: 0.0,
            virt1: fab.max_clock_s(),
        }],
        spans,
        registry: tracer.registry().snapshot(),
    };
    let trace_json = report.to_json().to_string();
    let mut bench = BTreeMap::new();
    bench.insert("total_bytes".to_string(), Json::Num(fab.total_bytes() as f64));
    bench.insert("intra_bytes".to_string(), Json::Num(fab.intra_bytes() as f64));
    bench.insert("inter_bytes".to_string(), Json::Num(fab.inter_bytes() as f64));
    bench.insert(
        "clock_bits".to_string(),
        Json::Arr((0..n).map(|r| Json::Str(format!("{:016x}", fab.clock_s(r).to_bits()))).collect()),
    );
    bench.insert(
        "idle_bits".to_string(),
        Json::Arr((0..n).map(|r| Json::Str(format!("{:016x}", fab.idle_s(r).to_bits()))).collect()),
    );
    let bench_json = Json::Obj(bench).to_string();
    (trace_json, bench_json)
}

#[test]
fn same_seed_means_bit_identical_artifacts_across_runs_and_poll_orders() {
    let (trace_a, bench_a) = fleet_fingerprint(ReadyPolicy::Fifo);
    let (trace_b, bench_b) = fleet_fingerprint(ReadyPolicy::Fifo);
    assert_eq!(trace_a, trace_b, "re-running the same seed must reproduce TRACE JSON bit-for-bit");
    assert_eq!(bench_a, bench_b, "re-running the same seed must reproduce BENCH JSON bit-for-bit");
    for policy in [ReadyPolicy::Lifo, ReadyPolicy::Shuffle(9), ReadyPolicy::Shuffle(0xFEED)] {
        let (trace_p, bench_p) = fleet_fingerprint(policy);
        assert_eq!(
            trace_a, trace_p,
            "{policy:?}: event-queue insertion order leaked into the TRACE artifact"
        );
        assert_eq!(
            bench_a, bench_p,
            "{policy:?}: event-queue insertion order leaked into the BENCH artifact"
        );
    }
}

// ------------------------------------------------ 3. golden jitter RNG

/// Both fabrics derive rank r's jitter stream as
/// `Rng::new(scenario.seed ^ mix64(r))`, one `next_f64` per send in
/// program order. Pin the first draws so any change to the seed path,
/// the mixer, or the f64 conversion fails here before it silently
/// breaks cross-fabric equivalence.
#[test]
fn per_rank_jitter_streams_match_golden_draws() {
    let golden: [(u64, [f64; 3]); 2] = [
        (0, [0.7005764821796896, 0.2787512294737843, 0.8396274618764198]),
        (1, [0.37560037338254704, 0.8881766665302357, 0.6845554503307507]),
    ];
    for (rank, want) in golden {
        let mut rng = Rng::new(7u64 ^ mix64(rank));
        for (i, w) in want.into_iter().enumerate() {
            let got = rng.next_f64();
            assert_eq!(
                got.to_bits(),
                w.to_bits(),
                "jitter stream drifted: seed=7 rank={rank} draw#{i}: {got} != {w}"
            );
        }
    }
}

// ------------------------------------------------ 4. elastic membership

/// Crash windows (`--crash R:A-B`) exclude ranks from the collective:
/// the sum covers exactly the alive members and dead ranks' clocks
/// never move (they rejoin at their old clock — lost-worker
/// semantics, world size unchanged).
#[test]
fn crash_windows_exclude_ranks_from_sum_and_freeze_their_clocks() {
    let n = 6usize;
    let d = 512usize;
    let scenario = Scenario {
        crashes: Scenario::parse_crashes("2:1-3,5:2-3").unwrap(),
        ..Scenario::none(11)
    };
    let ins = inputs(Family::Uniform, n, d, 0xCAFE);
    let dense: Vec<Vec<f32>> = ins.iter().map(|t| t.to_dense()).collect();
    let mut fab =
        FleetFabric::new(Topology::flat(n), Link::mbps(100.0), Link::mbps(100.0), scenario.clone());
    let codec = SegmentCodec::raw(0.5);
    let cfg = SparseConfig::default();
    for step in 0..4usize {
        let alive = scenario.alive_members(n, step);
        let inputs_step: Vec<SparseTensor> = alive.iter().map(|&r| ins[r].clone()).collect();
        let before: Vec<f64> = (0..n).map(|r| fab.clock_s(r)).collect();
        let outs = fab
            .allreduce_members(&alive, Schedule::GatherAll, &cfg, &codec, inputs_step)
            .unwrap();
        let mut want = vec![0.0f32; d];
        for &r in &alive {
            for (w, &v) in want.iter_mut().zip(&dense[r]) {
                *w += v;
            }
        }
        let got = outs[0].to_dense();
        assert_eq!(got.len(), want.len());
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert!((g - w).abs() < 1e-4, "step {step} elem {i}: {g} != {w}");
        }
        for r in 0..n {
            if alive.contains(&r) {
                assert!(fab.clock_s(r) > before[r], "step {step}: alive rank {r} must advance");
            } else {
                assert_eq!(fab.clock_s(r), before[r], "step {step}: dead rank {r} must freeze");
            }
        }
    }
}

// ------------------------------------------------------- 5. scale tier

fn scale_tests_enabled() -> bool {
    match std::env::var("DEEPREDUCE_SCALE_TESTS") {
        Ok(v) => v == "1",
        Err(_) => false,
    }
}

/// n disjoint, evenly-strided supports (the uniform load the simnet
/// closed forms assume exactly) — mirrors `tests/vfabric.rs`.
fn strided_inputs(n: usize, d: usize, k: usize) -> Vec<SparseTensor> {
    let m = d / k;
    (0..n)
        .map(|r| {
            let off = r * m / n;
            let idx: Vec<u32> = (0..k).map(|j| (j * m + off) as u32).collect();
            let val: Vec<f32> = (0..k).map(|j| 0.5 + ((r * k + j) % 97) as f32 / 100.0).collect();
            SparseTensor::new(d, idx, val)
        })
        .collect()
}

/// 1024 all-inter ranks: the fleet meters must land within ±2% of the
/// `simnet` chunked closed form (this run crosses the barrage gate, so
/// it also covers the fast path the n ≤ 8 points never reach).
#[test]
fn scale_chunked_inter_bytes_match_closed_form() {
    if !scale_tests_enabled() {
        eprintln!("SKIP: set DEEPREDUCE_SCALE_TESTS=1 to run the 1024-rank tier");
        return;
    }
    let n = 1024usize;
    let d = 1usize << 20;
    let k = 4096usize;
    let topo = Topology::new(n, 1); // every pair inter-node
    let ins = strided_inputs(n, d, k);
    let mut fab =
        FleetFabric::new(topo, Link::gbps(10.0), Link::mbps(100.0), Scenario::none(1));
    let cfg = SparseConfig { topology: Some(topo), ..SparseConfig::default() };
    let codec = SegmentCodec::raw(cfg.dense_switch);
    fab.allreduce(Schedule::ChunkedRescatter, &cfg, &codec, ins).unwrap();
    assert_eq!(fab.intra_bytes(), 0, "a 1024x1 grid has no intra links");
    let got = fab.inter_bytes() as f64;
    let want =
        chunked_rescatter_bytes(k as u64, d as u64, n, 0, SegWire::raw(cfg.dense_switch)) as f64;
    let rel = (got - want).abs() / want;
    assert!(
        rel <= 0.02,
        "chunked inter bytes off the closed form by {:.2}%: measured {got} vs model {want}",
        rel * 100.0
    );
}

/// On a 32×32 grid the hierarchical schedule must beat every
/// *all-to-all* flat schedule on inter-node bytes — the reason it
/// exists. The ring family is the deliberate exception: with the
/// blocked rank→node placement (`Topology::node_of = rank / rpn`) a
/// flat ring crosses only the 32 node-boundary links, so its inter
/// traffic is already near-minimal and *smaller* than the leaders'
/// O(nodes²) inner allgather — an independent byte-level mirror
/// simulation puts ring_rescatter_exact at ~13.3 MB vs hierarchical's
/// ~16.3 MB here. Both directions are pinned so the tradeoff cannot
/// silently drift.
#[test]
fn scale_hierarchical_beats_all_to_all_flat_schedules_on_inter_bytes() {
    if !scale_tests_enabled() {
        eprintln!("SKIP: set DEEPREDUCE_SCALE_TESTS=1 to run the 1024-rank tier");
        return;
    }
    let topo = Topology::new(32, 32);
    let n = topo.world();
    let d = 1usize << 16;
    let ins = strided_inputs(n, d, 64);
    let cfg = SparseConfig { topology: Some(topo), ..SparseConfig::default() };
    let codec = SegmentCodec::raw(cfg.dense_switch);
    let inter_of = |sched: Schedule| {
        let mut fab =
            FleetFabric::new(topo, Link::gbps(10.0), Link::mbps(100.0), Scenario::none(2));
        fab.allreduce(sched, &cfg, &codec, ins.clone()).unwrap();
        fab.inter_bytes()
    };
    let hier = inter_of(Schedule::Hierarchical);
    assert!(hier > 0, "hierarchical must cross node boundaries");
    for sched in [Schedule::GatherAll, Schedule::RecursiveDouble, Schedule::ChunkedRescatter] {
        let flat = inter_of(sched);
        assert!(
            hier < flat,
            "{sched:?}: hierarchical must use fewer inter bytes ({hier} vs {flat})"
        );
    }
    for sched in [Schedule::RingRescatter, Schedule::RingRescatterExact] {
        let ring = inter_of(sched);
        assert!(
            ring < hier,
            "{sched:?}: a node-contiguous flat ring crosses only the 32 boundary \
             links and must undercut the leaders' O(nodes²) inner allgather \
             ({ring} vs {hier})"
        );
    }
}

// --------------------------------------- 6. trainer fleet integration

use deepreduce::coordinator::{CompressionSpec, ModelKind, TrainConfig, Trainer};
use deepreduce::runtime::artifact_available;

fn mlp_cfg(fabric: &str, crash: &str) -> TrainConfig {
    let mut spec = CompressionSpec::topk(0.05, "raw", f64::NAN, "raw", f64::NAN);
    spec.schedule = "ring_rescatter_exact".into();
    spec.fabric = fabric.into();
    spec.crash = crash.into();
    spec.min_compress = 1;
    let mut cfg = TrainConfig::new(ModelKind::Mlp, "mlp");
    cfg.workers = 4;
    cfg.steps = 3;
    cfg.compression = Some(spec);
    cfg
}

/// `--fabric fleet` is a drop-in replacement for `--fabric virtual`:
/// losses bit-identical, wire traffic identical, measured step times
/// within 1e-9 (no threads anywhere near the gradient path).
#[test]
fn trainer_on_fleet_fabric_matches_threaded_virtual_fabric() {
    if !artifact_available("mlp") {
        eprintln!("SKIP: artifact mlp missing (run `make artifacts`)");
        return;
    }
    let rv = Trainer::new(mlp_cfg("virtual", "")).unwrap().run().unwrap();
    let rf = Trainer::new(mlp_cfg("fleet", "")).unwrap().run().unwrap();
    assert_eq!(rv.steps.len(), rf.steps.len());
    for (a, b) in rv.steps.iter().zip(&rf.steps) {
        assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "fabric must not change the math");
        assert_eq!(a.fabric_bytes, b.fabric_bytes, "same schedule, same wire traffic");
        assert_eq!(a.intra_bytes, b.intra_bytes);
        assert_eq!(a.inter_bytes, b.inter_bytes);
        assert!(
            (a.measured_step_s - b.measured_step_s).abs() <= 1e-9,
            "measured step time diverged: {} vs {}",
            a.measured_step_s,
            b.measured_step_s
        );
    }
}

/// A crash window changes the training math in exactly one way: the
/// crashed rank's gradient is lost for those steps.
#[test]
fn trainer_crash_window_runs_and_differs_from_baseline() {
    if !artifact_available("mlp") {
        eprintln!("SKIP: artifact mlp missing (run `make artifacts`)");
        return;
    }
    let base = Trainer::new(mlp_cfg("fleet", "")).unwrap().run().unwrap();
    let crashed = Trainer::new(mlp_cfg("fleet", "1:1-2")).unwrap().run().unwrap();
    assert_eq!(
        base.steps[0].loss.to_bits(),
        crashed.steps[0].loss.to_bits(),
        "before the crash window the runs are identical"
    );
    assert_ne!(
        base.steps[2].loss.to_bits(),
        crashed.steps[2].loss.to_bits(),
        "losing rank 1's step-1 gradient must change subsequent steps"
    );
}
