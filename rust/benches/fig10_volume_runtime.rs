//! Fig 10: (a) data-volume split into values vs indices for DeepReduce
//! instantiations + SKCompress on the Top-1% of a conv gradient
//! (d = 36864); (b) encode+decode wall-clock per method (log scale in
//! the paper — here a table with absolute times).

use deepreduce::compress::{index_by_name, value_by_name, DeepReduce};
use deepreduce::sparsify::{Sparsifier, TopK};
use deepreduce::util::benchkit::{fmt_duration, Bench, Table};
use deepreduce::util::prng::Rng;
use deepreduce::util::testkit::gradient_like;

fn main() {
    let d = 36_864;
    let mut rng = Rng::new(10);
    let grad = gradient_like(&mut rng, d);
    let mut topk = TopK::new(0.01);
    let sparse = topk.sparsify(&grad);
    let kv = sparse.kv_wire_bytes();
    println!("gradient d={d}, Top-1% r={} (kv baseline {kv} B)", sparse.nnz());

    let methods: Vec<(&str, &str, &str, f64)> = vec![
        ("Top-r (raw kv)", "raw", "raw", f64::NAN),
        ("DR[RLE | ∅]", "rle", "raw", f64::NAN),
        ("DR[Huffman | ∅]", "huffman", "raw", f64::NAN),
        ("DR[BF-P0 | ∅]", "bloom_p0", "raw", 0.001),
        ("DR[BF-P2 | ∅]", "bloom_p2", "raw", 0.001),
        ("DR[∅ | Deflate]", "raw", "deflate", f64::NAN),
        ("DR[∅ | QSGD-7b]", "raw", "qsgd", f64::NAN),
        ("DR[∅ | Fit-Poly]", "raw", "fitpoly", f64::NAN),
        ("DR[∅ | Fit-DExp]", "raw", "fitdexp", f64::NAN),
        ("DR[BF-P2 | Fit-Poly]", "bloom_p2", "fitpoly", 0.001),
        ("SKCompress", "delta_huffman", "sketch_huff", f64::NAN),
    ];

    let mut vol = Table::new(
        "Fig 10a — volume split (bytes)",
        &["method", "index", "values", "reorder", "total", "vs Top-r kv"],
    );
    let mut runtime = Table::new(
        "Fig 10b — encode / decode wall-clock",
        &["method", "encode", "decode", "total"],
    );
    let mut bench = Bench::new();
    for (label, idx, val, fpr) in methods {
        let dr = DeepReduce::new(
            index_by_name(idx, fpr, 3).unwrap(),
            value_by_name(val, f64::NAN, 3).unwrap(),
        );
        let c = dr.encode(&sparse, Some(&grad));
        let b = c.breakdown();
        vol.row(&[
            label.to_string(),
            b.index_bytes.to_string(),
            b.value_bytes.to_string(),
            b.reorder_bytes.to_string(),
            b.total().to_string(),
            format!("{:.3}", b.total() as f64 / kv as f64),
        ]);
        let enc = bench.run(&format!("{label} encode"), || {
            std::hint::black_box(dr.encode(std::hint::black_box(&sparse), Some(&grad)));
        });
        let enc_t = enc.median_s();
        let dec = bench.run(&format!("{label} decode"), || {
            std::hint::black_box(dr.decode(std::hint::black_box(&c)).unwrap());
        });
        let dec_t = dec.median_s();
        runtime.row(&[
            label.to_string(),
            fmt_duration(enc_t),
            fmt_duration(dec_t),
            fmt_duration(enc_t + dec_t),
        ]);
    }
    vol.print();
    runtime.print();
    println!("(paper shape: every DR row below Top-r kv; QSGD fastest of the");
    println!(" lossy coders; fit-based methods trade runtime for volume)");
}
