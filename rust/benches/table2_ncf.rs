//! Table 2: the inherently sparse model (NCF) — relative data volume and
//! hit rate for DR[BF-P2|Fit-Poly], DR[BF-P0|QSGD] and SKCompress.
//! Paper shape: all methods ≈ baseline hit rate; DR[BF-P0|QSGD] smallest
//! (0.2063), SKCompress close (0.2175), DR[BF-P2|Fit-Poly] larger
//! (0.5879) because of the reorder mapping.

use deepreduce::coordinator::{CompressionSpec, ModelKind};
use deepreduce::util::benchkit::Table;
use deepreduce::xp;

fn main() {
    if !xp::need("ncf") {
        return;
    }
    let steps = 40;
    let workers = xp::FIG_WORKERS;

    let runs = vec![
        ("Baseline".to_string(), xp::run(ModelKind::Ncf, "ncf", steps, workers, None).unwrap()),
        (
            "DR[BF-P2 | Fit-Poly] fpr=0.01".into(),
            xp::run(
                ModelKind::Ncf,
                "ncf",
                steps,
                workers,
                Some(CompressionSpec::identity("bloom_p2", 0.01, "fitpoly", 5.0)),
            )
            .unwrap(),
        ),
        (
            "SKCompress".into(),
            xp::run(
                ModelKind::Ncf,
                "ncf",
                steps,
                workers,
                Some(CompressionSpec::identity(
                    "delta_huffman",
                    f64::NAN,
                    "sketch_huff",
                    64.0,
                )),
            )
            .unwrap(),
        ),
        (
            "DR[BF-P0 | QSGD-7b] fpr=0.6".into(),
            xp::run(
                ModelKind::Ncf,
                "ncf",
                steps,
                workers,
                Some(CompressionSpec::identity("bloom_p0", 0.6, "qsgd", 7.0)),
            )
            .unwrap(),
        ),
    ];

    let mut table = Table::new(
        &format!("Table 2 — NCF (inherently sparse), {steps} steps, {workers} workers"),
        &["method", "rel data volume", "hit rate", "codec ms/step"],
    );
    for (n, r) in &runs {
        table.row(&[
            n.clone(),
            format!("{:.4}", r.relative_volume()),
            format!("{:.4}", r.final_aux(10)),
            format!("{:.1}", 1e3 * (r.total_encode_s() + r.total_decode_s()) / steps as f64),
        ]);
    }
    table.print();
    println!("(paper: 0.5879 / 0.2175 / 0.2063 rel volume; hit rates all ~equal)");
}
