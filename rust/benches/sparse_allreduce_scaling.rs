//! Sparse allreduce scaling sweep: world size n ∈ {2..32} × gradient
//! density × link speed, comparing the topology-aware schedules
//! (recursive doubling, ring rescatter) against the GatherAll baseline
//! and the dense ring allreduce. Fabric bytes are *measured* exactly on
//! the in-process transport; wall time is *modelled* with the matching
//! α–β cost models from `simnet` (validated against the wire in unit
//! tests, DESIGN.md §5). Runs without artifacts.

use deepreduce::collective::{Network, Schedule, SparseConfig};
use deepreduce::compress::index_by_name;
use deepreduce::simnet::{
    allreduce_time, gather_all_time, recursive_double_time, ring_rescatter_time, Link, SegWire,
};
use deepreduce::tensor::SparseTensor;
use deepreduce::util::benchkit::{BenchSummary, Table};
use deepreduce::util::json::Json;
use deepreduce::util::prng::Rng;
use deepreduce::util::testkit::sorted_support;
use std::collections::BTreeMap;
use std::thread;

/// Run one schedule across n threads; return total fabric bytes.
fn measured_bytes(sched: Schedule, inputs: &[SparseTensor]) -> u64 {
    let net = Network::new(inputs.len());
    let handles: Vec<_> = net
        .endpoints()
        .into_iter()
        .zip(inputs.to_vec())
        .map(|(ep, t)| {
            thread::spawn(move || sched.build(SparseConfig::default()).allreduce(&ep, t).unwrap())
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    net.total_bytes()
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let d = 1usize << 15;
    let w = SegWire::raw(0.5);
    let slow = Link::mbps(100.0);
    let fast = Link::gbps(10.0);
    let mut rng = Rng::new(42);
    let mut table = Table::new(
        "sparse allreduce scaling — measured fabric bytes, modelled α–β time",
        &["n", "density", "schedule", "fabric KB", "vs gather_all", "t@100Mbps", "t@10Gbps"],
    );
    let mut summary = BenchSummary::new("sparse_allreduce_scaling");
    let mut wins = 0usize;
    let mut cases = 0usize;
    let ns: &[usize] = if smoke { &[2, 4, 8] } else { &[2, 4, 8, 16, 32] };
    for &n in ns {
        for density in [0.01f64, 0.1] {
            let k = ((d as f64 * density) as usize).max(1);
            let inputs: Vec<SparseTensor> = (0..n)
                .map(|_| {
                    let support = sorted_support(&mut rng, d, k);
                    let values: Vec<f32> =
                        (0..k).map(|_| rng.next_gaussian() as f32).collect();
                    SparseTensor::new(d, support, values)
                })
                .collect();
            let ga_bytes = measured_bytes(Schedule::GatherAll, &inputs);
            // dense ring baseline: exact by construction, 2(n−1)·d·4 total
            let dense_bytes = 2 * (n as u64 - 1) * (d as u64) * 4;
            let (ku, du) = (k as u64, d as u64);
            let mut row = |name: &str, bytes: u64, t_slow: f64, t_fast: f64| {
                table.row(&[
                    n.to_string(),
                    format!("{density:.2}"),
                    name.to_string(),
                    format!("{:.1}", bytes as f64 / 1e3),
                    format!("{:.3}", bytes as f64 / ga_bytes as f64),
                    format!("{:.5}s", t_slow),
                    format!("{:.6}s", t_fast),
                ]);
                summary.row(&[
                    ("n", Json::Num(n as f64)),
                    ("density", Json::Num(density)),
                    ("schedule", Json::Str(name.to_string())),
                    ("fabric_bytes", Json::Num(bytes as f64)),
                    ("vs_gather_all", Json::Num(bytes as f64 / ga_bytes as f64)),
                    ("t_100mbps_s", Json::Num(t_slow)),
                    ("t_10gbps_s", Json::Num(t_fast)),
                ]);
            };
            row(
                "dense ring",
                dense_bytes,
                allreduce_time((d * 4) as u64, n, slow),
                allreduce_time((d * 4) as u64, n, fast),
            );
            row(
                "gather_all",
                ga_bytes,
                gather_all_time(ku, du, n, slow, w),
                gather_all_time(ku, du, n, fast, w),
            );
            let rd_bytes = measured_bytes(Schedule::RecursiveDouble, &inputs);
            row(
                "recursive_double",
                rd_bytes,
                recursive_double_time(ku, du, n, slow, w),
                recursive_double_time(ku, du, n, fast, w),
            );
            let rr_bytes = measured_bytes(Schedule::RingRescatter, &inputs);
            row(
                "ring_rescatter",
                rr_bytes,
                ring_rescatter_time(ku, du, n, slow, w, true),
                ring_rescatter_time(ku, du, n, fast, w, true),
            );
            let rre_bytes = measured_bytes(Schedule::RingRescatterExact, &inputs);
            row(
                "ring_rescatter_exact",
                rre_bytes,
                ring_rescatter_time(ku, du, n, slow, w, false),
                ring_rescatter_time(ku, du, n, fast, w, false),
            );
            // acceptance: at scale and sparse input, a topology-aware
            // schedule must move fewer bytes than the GatherAll baseline
            if n >= 8 && density <= 0.1 {
                cases += 1;
                let best = rd_bytes.min(rr_bytes);
                assert!(
                    best < ga_bytes,
                    "n={n} density={density}: best schedule {best} B \
                     not below gather_all {ga_bytes} B"
                );
                wins += 1;
            }
        }
    }
    table.print();

    // ---- composable index-codec chains on clustered supports -------
    // The paper's §3 claim is that stream representations compose
    // (e.g. RLE *then* Deflate on the index bytes). On a clustered
    // support the RLE stream is long and periodic, so the deflate tail
    // must shrink it — the chain has to beat single-stage rle outright.
    let dc = 1usize << 15;
    let clustered: Vec<u32> = (0..dc as u32).filter(|i| (i / 32) % 2 == 0).collect();
    let mut chains = Table::new(
        "index chains on a clustered support (32-on/32-off comb)",
        &["codec spec", "index bytes", "vs raw", "roundtrip"],
    );
    let mut chain_bytes = BTreeMap::new();
    let raw_bytes = clustered.len() * 4;
    for spec in ["raw", "rle", "rle+deflate", "elias", "elias+deflate", "bitmap+deflate"] {
        let codec = index_by_name(spec, f64::NAN, 1)
            .unwrap_or_else(|| panic!("registry spec {spec}"));
        let enc = codec.encode(dc, &clustered);
        let ok = codec.decode(dc, &enc.bytes).map(|s| s == clustered).unwrap_or(false);
        assert!(ok, "{spec} failed to roundtrip the clustered support");
        chains.row(&[
            spec.to_string(),
            enc.bytes.len().to_string(),
            format!("{:.4}", enc.bytes.len() as f64 / raw_bytes as f64),
            "ok".to_string(),
        ]);
        // full chain labels land in BENCH_sparse_allreduce_scaling.json
        // so the bench-trajectory artifacts distinguish chains from
        // single codecs
        summary.row(&[
            ("codec", Json::Str(spec.to_string())),
            ("index_bytes", Json::Num(enc.bytes.len() as f64)),
            ("vs_raw", Json::Num(enc.bytes.len() as f64 / raw_bytes as f64)),
        ]);
        chain_bytes.insert(spec, enc.bytes.len());
    }
    chains.print();
    let (rle, rle_deflate) = (chain_bytes["rle"], chain_bytes["rle+deflate"]);
    assert!(
        rle_deflate < rle,
        "rle+deflate ({rle_deflate} B) must beat single-stage rle ({rle} B) \
         on clustered index bytes"
    );
    summary.set("rle_bytes", Json::Num(rle as f64));
    summary.set("rle_deflate_bytes", Json::Num(rle_deflate as f64));
    println!(
        "chain win: rle+deflate {rle_deflate} B vs rle {rle} B on the clustered support \
         ({:.1}x smaller)",
        rle as f64 / rle_deflate as f64
    );

    summary.set("wins", Json::Num(wins as f64));
    summary.set("cases", Json::Num(cases as f64));
    summary.set("smoke", Json::Bool(smoke));
    match summary.write() {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write bench summary: {e}"),
    }
    println!(
        "topology-aware schedule beat gather_all in {wins}/{cases} at-scale configs \
         (n >= 8, density <= 10%)"
    );
    println!("(ring_rescatter re-sparsifies to ~k/n per chunk — the Ok-Topk trade;");
    println!(" ring_rescatter_exact and recursive_double return the exact sum)");
}
