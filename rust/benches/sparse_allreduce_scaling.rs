//! Sparse allreduce scaling sweep: world size n ∈ {2..32} × gradient
//! density × link speed, comparing the topology-aware schedules
//! (recursive doubling, ring rescatter) against the GatherAll baseline
//! and the dense ring allreduce. Fabric bytes are *measured* exactly on
//! the in-process transport; wall time is *modelled* with the matching
//! α–β cost models from `simnet` (validated against the wire in unit
//! tests, DESIGN.md §5). Runs without artifacts.

use deepreduce::collective::{Network, Schedule, SparseConfig};
use deepreduce::simnet::{
    allreduce_time, gather_all_time, recursive_double_time, ring_rescatter_time, Link, SegWire,
};
use deepreduce::tensor::SparseTensor;
use deepreduce::util::benchkit::{BenchSummary, Table};
use deepreduce::util::json::Json;
use deepreduce::util::prng::Rng;
use deepreduce::util::testkit::sorted_support;
use std::thread;

/// Run one schedule across n threads; return total fabric bytes.
fn measured_bytes(sched: Schedule, inputs: &[SparseTensor]) -> u64 {
    let net = Network::new(inputs.len());
    let handles: Vec<_> = net
        .endpoints()
        .into_iter()
        .zip(inputs.to_vec())
        .map(|(ep, t)| {
            thread::spawn(move || sched.build(SparseConfig::default()).allreduce(&ep, t).unwrap())
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    net.total_bytes()
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let d = 1usize << 15;
    let w = SegWire::raw(0.5);
    let slow = Link::mbps(100.0);
    let fast = Link::gbps(10.0);
    let mut rng = Rng::new(42);
    let mut table = Table::new(
        "sparse allreduce scaling — measured fabric bytes, modelled α–β time",
        &["n", "density", "schedule", "fabric KB", "vs gather_all", "t@100Mbps", "t@10Gbps"],
    );
    let mut summary = BenchSummary::new("sparse_allreduce_scaling");
    let mut wins = 0usize;
    let mut cases = 0usize;
    let ns: &[usize] = if smoke { &[2, 4, 8] } else { &[2, 4, 8, 16, 32] };
    for &n in ns {
        for density in [0.01f64, 0.1] {
            let k = ((d as f64 * density) as usize).max(1);
            let inputs: Vec<SparseTensor> = (0..n)
                .map(|_| {
                    let support = sorted_support(&mut rng, d, k);
                    let values: Vec<f32> =
                        (0..k).map(|_| rng.next_gaussian() as f32).collect();
                    SparseTensor::new(d, support, values)
                })
                .collect();
            let ga_bytes = measured_bytes(Schedule::GatherAll, &inputs);
            // dense ring baseline: exact by construction, 2(n−1)·d·4 total
            let dense_bytes = 2 * (n as u64 - 1) * (d as u64) * 4;
            let (ku, du) = (k as u64, d as u64);
            let mut row = |name: &str, bytes: u64, t_slow: f64, t_fast: f64| {
                table.row(&[
                    n.to_string(),
                    format!("{density:.2}"),
                    name.to_string(),
                    format!("{:.1}", bytes as f64 / 1e3),
                    format!("{:.3}", bytes as f64 / ga_bytes as f64),
                    format!("{:.5}s", t_slow),
                    format!("{:.6}s", t_fast),
                ]);
                summary.row(&[
                    ("n", Json::Num(n as f64)),
                    ("density", Json::Num(density)),
                    ("schedule", Json::Str(name.to_string())),
                    ("fabric_bytes", Json::Num(bytes as f64)),
                    ("vs_gather_all", Json::Num(bytes as f64 / ga_bytes as f64)),
                    ("t_100mbps_s", Json::Num(t_slow)),
                    ("t_10gbps_s", Json::Num(t_fast)),
                ]);
            };
            row(
                "dense ring",
                dense_bytes,
                allreduce_time((d * 4) as u64, n, slow),
                allreduce_time((d * 4) as u64, n, fast),
            );
            row(
                "gather_all",
                ga_bytes,
                gather_all_time(ku, du, n, slow, w),
                gather_all_time(ku, du, n, fast, w),
            );
            let rd_bytes = measured_bytes(Schedule::RecursiveDouble, &inputs);
            row(
                "recursive_double",
                rd_bytes,
                recursive_double_time(ku, du, n, slow, w),
                recursive_double_time(ku, du, n, fast, w),
            );
            let rr_bytes = measured_bytes(Schedule::RingRescatter, &inputs);
            row(
                "ring_rescatter",
                rr_bytes,
                ring_rescatter_time(ku, du, n, slow, w, true),
                ring_rescatter_time(ku, du, n, fast, w, true),
            );
            let rre_bytes = measured_bytes(Schedule::RingRescatterExact, &inputs);
            row(
                "ring_rescatter_exact",
                rre_bytes,
                ring_rescatter_time(ku, du, n, slow, w, false),
                ring_rescatter_time(ku, du, n, fast, w, false),
            );
            // acceptance: at scale and sparse input, a topology-aware
            // schedule must move fewer bytes than the GatherAll baseline
            if n >= 8 && density <= 0.1 {
                cases += 1;
                let best = rd_bytes.min(rr_bytes);
                assert!(
                    best < ga_bytes,
                    "n={n} density={density}: best schedule {best} B \
                     not below gather_all {ga_bytes} B"
                );
                wins += 1;
            }
        }
    }
    table.print();
    summary.set("wins", Json::Num(wins as f64));
    summary.set("cases", Json::Num(cases as f64));
    summary.set("smoke", Json::Bool(smoke));
    match summary.write() {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write bench summary: {e}"),
    }
    println!(
        "topology-aware schedule beat gather_all in {wins}/{cases} at-scale configs \
         (n >= 8, density <= 10%)"
    );
    println!("(ring_rescatter re-sparsifies to ~k/n per chunk — the Ok-Topk trade;");
    println!(" ring_rescatter_exact and recursive_double return the exact sum)");
}
