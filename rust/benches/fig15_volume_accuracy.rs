//! Fig 15 (appendix): data volume vs accuracy scatter for the Bloom
//! policies (incl. naive) against Top-r and the baseline, on the
//! ResNet-20 stand-in (a) and a DenseNet40-like second config with
//! Top-0.5% (b).

use deepreduce::coordinator::ModelKind;
use deepreduce::util::benchkit::Table;
use deepreduce::xp;

fn main() {
    if !xp::need("mlp") {
        return;
    }
    let steps = xp::FIG_STEPS;
    let workers = xp::FIG_WORKERS;
    let fpr = 0.001;

    for (panel, ratio) in [("(a) ResNet-20 stand-in, Top-1%", 0.01), ("(b) DenseNet40 stand-in, Top-0.5%", 0.005)]
    {
        let mut table = Table::new(
            &format!("Fig 15 {panel} — volume vs accuracy (FPR={fpr})"),
            &["method", "rel volume", "final acc"],
        );
        let base = xp::run(ModelKind::Mlp, "mlp", steps, workers, None).unwrap();
        table.row(&["baseline".into(), xp::pct(1.0), format!("{:.4}", base.final_aux(10))]);
        let plain =
            xp::run(ModelKind::Mlp, "mlp", steps, workers, Some(xp::dr_index(ratio, "raw", f64::NAN)))
                .unwrap();
        table.row(&[
            format!("Top-{}%", ratio * 100.0),
            xp::pct(plain.relative_volume()),
            format!("{:.4}", plain.final_aux(10)),
        ]);
        for policy in ["bloom_naive", "bloom_p0", "bloom_p1", "bloom_p2"] {
            let r = xp::run(
                ModelKind::Mlp,
                "mlp",
                steps,
                workers,
                Some(xp::dr_index(ratio, policy, fpr)),
            )
            .unwrap();
            table.row(&[
                policy.to_string(),
                xp::pct(r.relative_volume()),
                format!("{:.4}", r.final_aux(10)),
            ]);
        }
        table.print();
    }
    println!("(paper shape: P0/P2 sit at Top-r accuracy with less volume;");
    println!(" naive falls off the accuracy axis)");
}
