//! Pipeline scaling sweep: buckets × density × link, comparing the
//! bucketed, overlapped gradient pipeline against the unbucketed
//! per-tensor path, plus the codec-autotuning density sweep.
//!
//! Encode/decode seconds are *measured* on this testbed; transfer time
//! is *modelled* with the simnet α–β link model on the exact container
//! bytes, and serial vs. double-buffered step time comes from
//! `simnet::{serial_step_time, pipelined_step_time}` (DESIGN.md §6).
//! Runs without artifacts.
//!
//! Acceptance (asserted):
//!  - the overlapped bucketed path beats the unbucketed per-tensor path
//!    in modelled step time for the multi-tensor workload;
//!  - the autotuner picks at least two distinct codec pairs across a
//!    density sweep.

use deepreduce::compress::CompressSpec;
use deepreduce::pipeline::{CodecPolicy, GradientPipeline, StepTimeline};
use deepreduce::simnet::Link;
use deepreduce::sparsify::Sparsifier;
use deepreduce::tensor::SparseTensor;
use deepreduce::util::benchkit::{BenchSummary, Table};
use deepreduce::util::json::Json;
use deepreduce::util::prng::Rng;
use deepreduce::util::testkit::gradient_like;

/// A transformer-ish multi-tensor step: embeddings, attention blocks,
/// MLP blocks, head — 12 tensors, ~0.3M parameters.
const SIZES: [usize; 12] =
    [50_304, 16_384, 4_096, 4_096, 65_536, 16_384, 4_096, 4_096, 65_536, 16_384, 2_048, 2_048];

/// Run one worker's step through the pipeline; returns the timeline and
/// total container bytes.
fn run_step(
    pipe: &mut GradientPipeline,
    grads: &[Vec<f32>],
    sparse: &[SparseTensor],
) -> (StepTimeline, u64, Vec<String>) {
    let buckets = pipe.plan().buckets.clone();
    let mut timeline = StepTimeline::new();
    let mut bytes = 0u64;
    let mut labels: Vec<String> = Vec::new();
    for bucket in &buckets {
        let parts: Vec<&SparseTensor> = bucket.tensors.iter().map(|&ti| &sparse[ti]).collect();
        let dense_parts: Vec<&[f32]> =
            bucket.tensors.iter().map(|&ti| grads[ti].as_slice()).collect();
        let enc = pipe.encode_bucket(bucket, &parts, &dense_parts).expect("encode bucket");
        timeline.push(enc.encode_s, enc.comm_model_s);
        bytes += enc.wire_bytes;
        if !labels.contains(&enc.choice_label) {
            labels.push(enc.choice_label);
        }
    }
    (timeline, bytes, labels)
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let workers = 4;
    let mut rng = Rng::new(0x9195);
    let grads: Vec<Vec<f32>> = SIZES.iter().map(|&s| gradient_like(&mut rng, s)).collect();
    let members: Vec<(usize, usize)> = SIZES.iter().copied().enumerate().collect();

    let mut table = Table::new(
        "pipeline scaling — measured encode, α–β modelled transfer",
        &[
            "density", "link", "bucket cap", "buckets", "KB/worker", "serial ms",
            "overlapped ms", "vs per-tensor serial",
        ],
    );
    let mut summary = BenchSummary::new("pipeline_scaling");
    let mut wins = 0usize;
    let mut cases = 0usize;
    let densities: &[f64] = if smoke { &[0.01] } else { &[0.01, 0.05, 0.2] };
    for &density in densities {
        let sparse: Vec<SparseTensor> = grads
            .iter()
            .map(|g| {
                let mut topk = deepreduce::sparsify::TopK::new(density);
                topk.sparsify(g)
            })
            .collect();
        let links = [
            ("100Mbps", Link::mbps(100.0)),
            ("1Gbps", Link::gbps(1.0)),
            ("10Gbps", Link::gbps(10.0)),
        ];
        for (lname, link) in links {
            let mut per_tensor_serial = f64::NAN;
            for (cname, cap) in [("per-tensor", 0usize), ("256KiB", 256 << 10), ("1MiB", 1 << 20)] {
                let mut pipe = GradientPipeline::new(
                    &members,
                    cap,
                    false,
                    true,
                    &CompressSpec::raw(),
                    7,
                    link,
                    workers,
                )
                .expect("pipeline");
                let nbuckets = pipe.plan().len();
                let (timeline, bytes, _) = run_step(&mut pipe, &grads, &sparse);
                let serial = timeline.serial_s();
                let overlapped = timeline.pipelined_s();
                if cap == 0 {
                    per_tensor_serial = serial;
                }
                table.row(&[
                    format!("{density:.2}"),
                    lname.to_string(),
                    cname.to_string(),
                    nbuckets.to_string(),
                    format!("{:.1}", bytes as f64 / 1e3),
                    format!("{:.3}", serial * 1e3),
                    format!("{:.3}", overlapped * 1e3),
                    format!("{:.3}x", per_tensor_serial / overlapped),
                ]);
                summary.row(&[
                    ("density", Json::Num(density)),
                    ("link", Json::Str(lname.to_string())),
                    ("bucket_cap", Json::Str(cname.to_string())),
                    ("buckets", Json::Num(nbuckets as f64)),
                    ("bytes_per_worker", Json::Num(bytes as f64)),
                    ("serial_s", Json::Num(serial)),
                    ("overlapped_s", Json::Num(overlapped)),
                ]);
                // acceptance: fused buckets + overlap must beat the
                // unbucketed, unoverlapped per-tensor path
                if cap > 0 {
                    cases += 1;
                    if overlapped < per_tensor_serial {
                        wins += 1;
                    }
                    assert!(
                        overlapped < per_tensor_serial,
                        "density {density} link {lname} cap {cname}: overlapped {overlapped}s \
                         not below per-tensor serial {per_tensor_serial}s"
                    );
                }
            }
        }
    }
    table.print();
    summary.set("wins", Json::Num(wins as f64));
    summary.set("cases", Json::Num(cases as f64));
    summary.set("smoke", Json::Bool(smoke));
    println!("overlapped bucketed path beat the per-tensor serial path in {wins}/{cases} configs");

    // ---- codec autotuning across a density sweep ------------------
    // byte-calibrated policy (deterministic choices; throughput terms
    // zeroed) on a slow link where wire bytes dominate the cost. The
    // candidate set is enumerated from the codec registry, chains
    // (e.g. rle+deflate) included — nothing here names codecs.
    let (idx_candidates, val_candidates) = deepreduce::pipeline::default_candidates(false);
    let policy = CodecPolicy::calibrate_bytes_only(
        &idx_candidates,
        &val_candidates,
        7,
        Link::mbps(10.0),
        workers,
    );
    let d = 1 << 16;
    let mut sweep = Table::new(
        "autotuned codec choice vs density (argmin of encode + α–β transfer)",
        &["density", "nnz", "index|value", "est KB"],
    );
    let mut picks: Vec<String> = Vec::new();
    for &density in &[0.001f64, 0.01, 0.05, 0.2, 0.6, 1.0] {
        let nnz = ((d as f64 * density) as usize).max(1);
        let choice = policy.choose(d, nnz);
        let label = choice.label();
        let ip = policy
            .index_profiles
            .iter()
            .find(|p| p.name == choice.index)
            .expect("chosen index profile");
        let vp = policy
            .value_profiles
            .iter()
            .find(|p| p.name == choice.value)
            .expect("chosen value profile");
        let est = policy.estimate_bytes(ip, vp, d, nnz);
        sweep.row(&[
            format!("{density:.3}"),
            nnz.to_string(),
            label.clone(),
            format!("{:.1}", est / 1e3),
        ]);
        // full spec labels (chains included) into the bench artifact so
        // BENCH_pipeline_scaling.json distinguishes rle+deflate from rle
        summary.row(&[
            ("autotune_density", Json::Num(density)),
            ("autotune_choice", Json::Str(label.clone())),
            ("est_bytes", Json::Num(est)),
        ]);
        if !picks.contains(&label) {
            picks.push(label);
        }
    }
    sweep.print();
    println!("distinct codec pairs across the sweep: {picks:?}");
    assert!(
        picks.len() >= 2,
        "autotuner picked only {picks:?} across the density sweep — expected >= 2 distinct pairs"
    );

    // and through the full pipeline (measured calibration): report the
    // labels the integrated autotuner actually used on this workload
    let mut tuned = GradientPipeline::new(
        &members,
        1 << 20,
        true,
        true,
        &CompressSpec::raw(),
        7,
        Link::mbps(10.0),
        workers,
    )
    .expect("autotuned pipeline");
    let sparse: Vec<SparseTensor> = grads
        .iter()
        .map(|g| {
            let mut topk = deepreduce::sparsify::TopK::new(0.02);
            topk.sparsify(g)
        })
        .collect();
    let (_, _, labels) = run_step(&mut tuned, &grads, &sparse);
    println!("integrated autotuner on the 2% workload picked: {labels:?}");
    summary.set(
        "integrated_autotune_choices",
        Json::Arr(labels.iter().map(|l| Json::Str(l.clone())).collect()),
    );
    match summary.write() {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write bench summary: {e}"),
    }
}
