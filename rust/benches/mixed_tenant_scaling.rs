//! Mixed-tenant scaling: the multi-tenant reduction service's isolation
//! and warm-start acceptance gates, measured (`deepreduce::service`).
//!
//! Three legs:
//!
//! 1. **shared leg** — 4 concurrent jobs (1 dense + 3 sparse tenants, 4
//!    ranks each, one node per job) interleaved by the fair-share
//!    scheduler on ONE fleet fabric for R rounds.
//! 2. **isolated leg** — the same 4 jobs re-run one-per-service on an
//!    identical fabric, each stepped exactly as many times as it
//!    stepped in the shared run.
//! 3. **warm-start leg** — an autotuned job cold-calibrates, persists
//!    its `PROFILE_*.json`, and a second submit of the same
//!    (model, topology, link) key warm-loads it.
//!
//! Acceptance (asserted below):
//!   - aggregate shared throughput (Σ steps / virtual s) within 15% of
//!     the sum of the isolated runs — jobs on disjoint placements must
//!     not contend (the registry hands out disjoint rank sets and the
//!     event loop only touches member ports);
//!   - no tenant starved: every job completes at least one step per
//!     scheduling round (the deficit scheduler's progress floor);
//!   - the warm submit's setup time and first-step time are strictly
//!     below the cold submit's (profile load replaces the calibration
//!     sweep).
//!
//! Writes `BENCH_mixed_tenant_scaling.json`. `--smoke` runs the
//! reduced sweep CI uses.

use deepreduce::collective::Topology;
use deepreduce::service::{JobId, JobRequest, ReductionService, ServiceConfig};
use deepreduce::simnet::Link;
use deepreduce::util::benchkit::{BenchSummary, Table};
use deepreduce::util::json::Json;

/// The fabric both legs run on: 4 nodes × 4 ranks, fast intra links,
/// slow inter links (a job placed on one node never meters inter).
fn config() -> ServiceConfig {
    ServiceConfig::new(Topology::new(4, 4), Link::mbps(10_000.0), Link::mbps(100.0))
}

/// The tenant mix: one dense job next to three sparse ones, all equal
/// weight — the adversarial shape for a byte-fair scheduler (the dense
/// tenant's steps are ~50x the bytes of a sparse tenant's).
fn tenant_mix(dim: usize) -> Vec<JobRequest> {
    let mut reqs = vec![JobRequest {
        seed: 0xBEEF,
        ..JobRequest::synthetic("dense0", 4, dim, 0.5)
    }];
    for i in 0..3 {
        reqs.push(JobRequest {
            seed: 0xBEEF ^ (i + 1) as u64,
            ..JobRequest::synthetic(&format!("sparse{i}"), 4, dim, 0.01)
        });
    }
    reqs
}

/// steps / accumulated virtual seconds for one finished-or-running job.
fn throughput(svc: &ReductionService, id: JobId) -> f64 {
    let job = svc.job(id).expect("job stays queryable");
    job.steps as f64 / job.virtual_s.max(f64::EPSILON)
}

fn main() {
    let argv: Vec<String> = std::env::args().collect();
    let smoke = argv.iter().any(|a| a == "--smoke");
    let (dim, rounds) = if smoke { (1usize << 13, 5usize) } else { (1usize << 16, 12usize) };
    let mut summary = BenchSummary::new("mixed_tenant_scaling");
    summary.set("smoke", Json::Bool(smoke));
    summary.set("dim", Json::Num(dim as f64));
    summary.set("rounds", Json::Num(rounds as f64));

    // ---- shared leg: 4 tenants interleaved on one fabric ----
    let mut shared = ReductionService::new(config());
    let ids: Vec<JobId> = tenant_mix(dim)
        .into_iter()
        .map(|req| shared.submit(req).expect("mix fits the 4x4 fabric"))
        .collect();
    for _ in 0..rounds {
        shared.run_round().expect("round");
    }
    let mut table = Table::new(
        &format!("mixed tenants — {rounds} fair-share rounds, dim {dim}"),
        &["job", "steps", "shared steps/s", "isolated steps/s", "intra B"],
    );
    let mut agg_shared = 0.0;
    let mut agg_isolated = 0.0;
    let mut min_steps = u64::MAX;
    for &id in &ids {
        let (name, steps, bytes) = {
            let job = shared.job(id).expect("admitted");
            assert_eq!(job.bytes[1], 0, "{} spans one node, must not meter inter", job.name);
            (job.name.clone(), job.steps, job.bytes[0])
        };
        min_steps = min_steps.min(steps);
        let tp_shared = throughput(&shared, id);
        agg_shared += tp_shared;

        // ---- isolated leg: same job alone on an identical fabric ----
        let mut solo = ReductionService::new(config());
        let req = tenant_mix(dim)
            .into_iter()
            .find(|r| r.name == name)
            .expect("mix contains the job");
        let solo_id = solo.submit(req).expect("single tenant always fits");
        for _ in 0..steps {
            solo.step_job(solo_id).expect("step");
        }
        let tp_solo = throughput(&solo, solo_id);
        agg_isolated += tp_solo;

        table.row(&[
            name.clone(),
            steps.to_string(),
            format!("{tp_shared:.2}"),
            format!("{tp_solo:.2}"),
            bytes.to_string(),
        ]);
        summary.row(&[
            ("leg", Json::Str("scaling".to_string())),
            ("job", Json::Str(name)),
            ("steps", Json::Num(steps as f64)),
            ("shared_steps_per_s", Json::Num(tp_shared)),
            ("isolated_steps_per_s", Json::Num(tp_solo)),
            ("intra_bytes", Json::Num(bytes as f64)),
        ]);
    }
    table.print();
    for id in ids {
        shared.finish(id).expect("finish");
    }
    let gap = (agg_shared - agg_isolated).abs() / agg_isolated.max(f64::EPSILON);
    summary.set("aggregate_shared_steps_per_s", Json::Num(agg_shared));
    summary.set("aggregate_isolated_steps_per_s", Json::Num(agg_isolated));
    summary.set("aggregate_gap_frac", Json::Num(gap));
    summary.set("min_steps", Json::Num(min_steps as f64));
    assert!(
        gap <= 0.15,
        "shared aggregate {agg_shared:.2} steps/s deviates {:.1}% from the isolated \
         sum {agg_isolated:.2} (acceptance bar 15%)",
        gap * 100.0
    );
    assert!(
        min_steps >= rounds as u64,
        "a tenant starved: {min_steps} steps over {rounds} rounds \
         (the progress floor owes one step per tenant per round)"
    );
    println!(
        "  [isolation] aggregate {agg_shared:.2} steps/s shared vs {agg_isolated:.2} isolated \
         ({:+.1}%, bar 15%); min {min_steps} steps over {rounds} rounds — no starvation",
        gap * 100.0
    );

    // ---- warm-start leg: cold calibration, persist, warm reload ----
    let dir = std::env::temp_dir().join(format!("deepreduce_mixed_tenant_{}", std::process::id()));
    let autotuned = |name: &str| JobRequest {
        model: "warmtest".to_string(),
        autotune: true,
        seed: 0xC0FFEE,
        ..JobRequest::synthetic(name, 4, dim, 0.01)
    };
    let mut cold_svc = ReductionService::new(config().with_profiles(dir.clone()));
    let cold_id = cold_svc.submit(autotuned("cold")).expect("cold admit");
    cold_svc.step_job(cold_id).expect("cold step");
    let cold = {
        let job = cold_svc.job(cold_id).expect("cold job");
        assert!(!job.setup.warm_start, "no profile exists yet");
        (job.setup.total_s(), job.first_step_s.expect("stepped"))
    };
    let profile = cold_svc.finish(cold_id).expect("finish").expect("autotuned job persists");
    println!("  [warm-start] profile persisted to {}", profile.display());

    let mut warm_svc = ReductionService::new(config().with_profiles(dir.clone()));
    let warm_id = warm_svc.submit(autotuned("warm")).expect("warm admit");
    warm_svc.step_job(warm_id).expect("warm step");
    let warm = {
        let job = warm_svc.job(warm_id).expect("warm job");
        assert!(job.setup.warm_start, "second submit of the key must warm-load");
        (job.setup.total_s(), job.first_step_s.expect("stepped"))
    };
    warm_svc.finish(warm_id).expect("finish");
    let _ = std::fs::remove_dir_all(&dir);
    summary.row(&[
        ("leg", Json::Str("warm_start".to_string())),
        ("cold_setup_s", Json::Num(cold.0)),
        ("warm_setup_s", Json::Num(warm.0)),
        ("cold_first_step_s", Json::Num(cold.1)),
        ("warm_first_step_s", Json::Num(warm.1)),
    ]);
    assert!(
        warm.0 < cold.0 && warm.1 < cold.1,
        "warm start must beat cold: setup {:.6}s vs {:.6}s, first step {:.6}s vs {:.6}s",
        warm.0,
        cold.0,
        warm.1,
        cold.1
    );
    println!(
        "  [warm-start] setup {:.6}s warm vs {:.6}s cold; first step {:.6}s vs {:.6}s",
        warm.0, cold.0, warm.1, cold.1
    );

    match summary.write() {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write bench summary: {e}"),
    }
}
