//! Virtual-time fabric scaling sweep: schedule × scenario (homogeneous
//! baseline, compute+link stragglers, heterogeneous per-node links),
//! with step times **measured** on the event-driven virtual-clock
//! fabric (`deepreduce::vfabric`) instead of modelled by the α–β
//! closed forms. Runs without artifacts.
//!
//! The point of the sweep: the closed forms assign every schedule the
//! same relative cost no matter the conditions, but measured virtual
//! time shows the schedule *ranking inverting* under conditions the
//! formulas cannot see — a straggler's slow NIC punishes GatherAll's
//! O(n·k) blobs far harder than RingRescatter's O(k) chunks, flipping
//! the winner at low density (SparCML's observation that the best
//! sparse schedule depends on network conditions, now reproduced as a
//! measurement).
//!
//! Acceptance (asserted below): at least one schedule pair swaps order
//! (by measured virtual time, with a 2% margin) between the
//! homogeneous baseline and a straggler or heterogeneous-link
//! scenario; and the chunked schedule beats the exact ring under the
//! straggler at every swept density (its pairwise exchange ships O(k)
//! through the slow NIC where the ring forwards accumulated chunks).
//!
//! `--smoke` runs the reduced sweep CI uses.

//! `--fabric fleet [--ranks N]` switches the sweep onto the
//! single-threaded fleet event-loop runner (`deepreduce::fleetsim`):
//! same schedules, same virtual clocks and byte meters, no OS threads —
//! the path that scales to 10k ranks (see the README fleet-scale
//! cookbook). At ≥4096 ranks the chunked step must finish under 60 s
//! of wall time (asserted).

use deepreduce::collective::sparse::SegmentCodec;
use deepreduce::collective::{Schedule, SparseConfig, Topology};
use deepreduce::fleetsim::FleetFabric;
use deepreduce::obs::{self, Lane, Span, SpanKind, StepWindow, TraceLevel, TraceReport, Tracer};
use deepreduce::simnet::{flat_schedule_time, Link, SegWire};
use deepreduce::tensor::SparseTensor;
use deepreduce::util::benchkit::{BenchSummary, Table};
use deepreduce::util::json::Json;
use deepreduce::util::prng::Rng;
use deepreduce::util::testkit::{scenario_corpus, sorted_support};
use deepreduce::vfabric::{LinkFlap, Scenario, VirtualNetwork};
use std::collections::{BTreeMap, BTreeSet};
use std::thread;

/// Run one schedule over the virtual fabric; returns (measured
/// critical-path seconds, total rank idle seconds, fabric bytes).
fn measured(
    sched: Schedule,
    topo: Topology,
    intra: Link,
    inter: Link,
    scenario: &Scenario,
    inputs: &[SparseTensor],
) -> (f64, f64, u64) {
    let net = VirtualNetwork::new(topo, intra, inter, scenario.clone());
    let cfg = SparseConfig { topology: Some(topo), ..SparseConfig::default() };
    let handles: Vec<_> = net
        .endpoints()
        .into_iter()
        .zip(inputs.to_vec())
        .map(|(ep, t)| thread::spawn(move || sched.build(cfg).allreduce(&ep, t).unwrap()))
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    (net.max_clock_s(), net.total_idle_s(), net.total_bytes())
}

/// Re-run the straggler case with full tracing installed and return the
/// reconciliation coverage: the fraction of the measured virtual step
/// the traced critical path (compute + recv_wait + barrier on the
/// slowest rank) accounts for. Exact by construction — the virtual
/// clock only advances through `elapse` and recv waits — so anything
/// below ~100% means an instrumentation gap (DESIGN.md §11).
fn traced_coverage(
    topo: Topology,
    link: Link,
    scenario: &Scenario,
    inputs: &[SparseTensor],
) -> (f64, TraceReport) {
    let n = topo.world();
    let tracer = Tracer::new(TraceLevel::Full, n);
    let net = VirtualNetwork::new(topo, link, link, scenario.clone());
    let cfg = SparseConfig { topology: Some(topo), ..SparseConfig::default() };
    let base_compute = 2e-3;
    let handles: Vec<_> = net
        .endpoints()
        .into_iter()
        .zip(inputs.to_vec())
        .enumerate()
        .map(|(r, (ep, t))| {
            let tracer = tracer.clone();
            let factor = scenario.compute_factor(r, 0);
            thread::spawn(move || {
                let _bind = tracer.install(r);
                ep.sync_to(0.0);
                {
                    let mut sp = obs::span(SpanKind::Compute);
                    sp.label_with(|| "replay".to_string());
                    ep.elapse(base_compute * factor);
                }
                Schedule::GatherAll.build(cfg).allreduce(&ep, t).unwrap();
                ep.now()
            })
        })
        .collect();
    let ends: Vec<f64> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    let step_end = ends.iter().copied().fold(0.0, f64::max);
    for (r, &e) in ends.iter().enumerate() {
        tracer.record(Span {
            kind: SpanKind::Barrier,
            lane: Lane::Cpu,
            rank: r as u32,
            step: 0,
            depth: 0,
            bytes: 0,
            label: None,
            wall0: f64::NAN,
            wall1: f64::NAN,
            virt0: e,
            virt1: step_end,
        });
    }
    let report = TraceReport {
        name: "vfabric_scaling".to_string(),
        level: TraceLevel::Full,
        ranks: n,
        meta: BTreeMap::from([
            ("schedule".to_string(), Json::Str("gather_all".to_string())),
            ("scenario".to_string(), Json::Str("straggler 0:16".to_string())),
        ]),
        steps: vec![StepWindow {
            step: 0,
            measured_s: step_end,
            idle_mean_s: net.total_idle_s() / n as f64,
            virt0: 0.0,
            virt1: step_end,
        }],
        spans: tracer.drain(0),
        registry: tracer.registry().snapshot(),
    };
    let cov = report.reconciliation(0).expect("virtual trace data");
    (cov, report)
}

/// Run one schedule on the single-threaded fleet event-loop runner;
/// returns (measured critical-path seconds, total rank idle seconds,
/// (total, intra, inter) fabric bytes). The event-loop twin of
/// [`measured`] — byte- and virtual-time-identical to it at every
/// differential point (`tests/fleetsim_equivalence.rs`), but with no
/// OS threads, which is what lets the sweep scale to 10k ranks.
fn measured_fleet(
    sched: Schedule,
    topo: Topology,
    intra: Link,
    inter: Link,
    scenario: &Scenario,
    inputs: &[SparseTensor],
) -> (f64, f64, (u64, u64, u64)) {
    let mut fabric = FleetFabric::new(topo, intra, inter, scenario.clone());
    let cfg = SparseConfig { topology: Some(topo), ..SparseConfig::default() };
    let codec = SegmentCodec::raw(cfg.dense_switch);
    fabric.allreduce(sched, &cfg, &codec, inputs.to_vec()).unwrap();
    (
        fabric.max_clock_s(),
        fabric.total_idle_s(),
        (fabric.total_bytes(), fabric.intra_bytes(), fabric.inter_bytes()),
    )
}

/// The `--fabric fleet` sweep. Three legs:
///
/// 1. **corpus leg** (n = 8): every flat schedule × every
///    [`scenario_corpus`] entry on the fleet runner, with a threaded
///    cross-check (GatherAll clocks must agree to ±1e-9) — a cheap
///    bench-level echo of the differential test suite.
/// 2. **scale leg** (n = `--ranks`, default 4096): one
///    `chunked_rescatter` step at d = 2^20, density 0.001 on a flat
///    topology under the inactive scenario (the barrage fast path).
///    Asserts the step stays under 60 s of wall time at n ≥ 4096 —
///    the fleet-scale acceptance bar (see the README cookbook).
/// 3. **health leg** (n = `--ranks`): chunked steps on a node grid
///    under `--straggler 0:16 --link-flap 1:0-1000000:4` with the
///    sampled telemetry plane on — the detector must recover exactly
///    the injected straggler rank from the folded histograms, exemplar
///    traces must stay bounded by the K budget, and the leg records
///    the aggregation overhead against an untraced twin step (the
///    `HEALTH_vfabric_scaling_fleet.json` artifact CI validates).
fn fleet_sweep(ranks: usize, smoke: bool) {
    // distinct summary name: CI runs both modes and BENCH_<name>.json
    // lands at the repo root — same name would clobber the threaded run
    let mut summary = BenchSummary::new("vfabric_scaling_fleet");
    summary.set("fabric", Json::Str("fleet".to_string()));
    summary.set("ranks", Json::Num(ranks as f64));
    summary.set("smoke", Json::Bool(smoke));
    let slow = Link::mbps(100.0);
    let mut rng = Rng::new(42);

    // ---- corpus leg: n = 8, all flat schedules × scenario corpus ----
    let n = 8usize;
    let d = 1usize << 15;
    let k = ((d as f64 * 0.001) as usize).max(1);
    let inputs: Vec<SparseTensor> = (0..n)
        .map(|_| {
            let support = sorted_support(&mut rng, d, k);
            let values: Vec<f32> = (0..k).map(|_| rng.next_gaussian() as f32).collect();
            SparseTensor::new(d, support, values)
        })
        .collect();
    let mut table = Table::new(
        "fleet event-loop runner — measured virtual step time, scenario corpus @ n=8",
        &["scenario", "schedule", "measured", "idle(sum)", "bytes"],
    );
    let corpus = scenario_corpus(7, n);
    let labels = ["baseline", "straggled", "jittery", "hetero", "flappy", "stormy"];
    for (scenario, label) in corpus.iter().zip(labels) {
        for sched in Schedule::flat() {
            let (t, idle, (bytes, _, _)) =
                measured_fleet(sched, Topology::flat(n), slow, slow, scenario, &inputs);
            table.row(&[
                label.to_string(),
                sched.name().to_string(),
                format!("{:.3}ms", t * 1e3),
                format!("{:.3}ms", idle * 1e3),
                format!("{bytes}"),
            ]);
            summary.row(&[
                ("leg", Json::Str("corpus".to_string())),
                ("scenario", Json::Str(label.to_string())),
                ("schedule", Json::Str(sched.name().to_string())),
                ("measured_s", Json::Num(t)),
                ("idle_s", Json::Num(idle)),
                ("fabric_bytes", Json::Num(bytes as f64)),
            ]);
        }
        // cross-check against the threaded fabric: the differential
        // suite pins all schedules; one per scenario keeps the bench
        // honest without re-running it
        let (ft, fi, _) =
            measured_fleet(Schedule::GatherAll, Topology::flat(n), slow, slow, scenario, &inputs);
        let (tt, ti, _) =
            measured(Schedule::GatherAll, Topology::flat(n), slow, slow, scenario, &inputs);
        assert!(
            (ft - tt).abs() <= 1e-9 && (fi - ti).abs() <= 1e-9,
            "fleet/threaded divergence under {label}: clock {ft} vs {tt}, idle {fi} vs {ti}"
        );
    }
    table.print();
    println!("  [cross-check] fleet == threaded (±1e-9) across {} corpus scenarios", corpus.len());

    // ---- scale leg: one step at `ranks` ranks ----
    let d = 1usize << 20;
    let k = ((d as f64 * 0.001) as usize).max(1);
    let scale_inputs: Vec<SparseTensor> = (0..ranks)
        .map(|r| {
            // lattice supports: deterministic and cheap (sampling via
            // Rng at 10k ranks would dominate setup time); an odd
            // multiplier is invertible mod the power-of-two domain, so
            // each rank gets exactly k distinct indices
            let a = ranks | 1;
            let mut support: Vec<u32> = (0..k).map(|i| ((i * a + r) % d) as u32).collect();
            support.sort_unstable();
            support.dedup();
            let values: Vec<f32> =
                (0..support.len()).map(|i| (i % 7) as f32 * 0.25 + 0.5).collect();
            SparseTensor::new(d, support, values)
        })
        .collect();
    let mut scale_table = Table::new(
        "fleet event-loop runner — fleet-scale single step",
        &["ranks", "schedule", "virtual", "wall", "inter bytes"],
    );
    // chunked only: gather_all's merge cost is O(n·min(n·k, d)) per
    // rank — the accumulator densifies at d, which at 4096+ ranks is
    // ~1e13 element ops fleet-wide. The chunked schedule's per-rank
    // work stays O(n·k/n + k) and is the scale story being measured.
    for sched in [Schedule::ChunkedRescatter] {
        let t0 = std::time::Instant::now();
        let (t, idle, (_, _, inter)) = measured_fleet(
            sched,
            Topology::flat(ranks),
            slow,
            slow,
            &Scenario::none(7),
            &scale_inputs,
        );
        let wall = t0.elapsed().as_secs_f64();
        scale_table.row(&[
            format!("{ranks}"),
            sched.name().to_string(),
            format!("{t:.3}s"),
            format!("{wall:.2}s"),
            format!("{inter}"),
        ]);
        summary.row(&[
            ("leg", Json::Str("scale".to_string())),
            ("ranks", Json::Num(ranks as f64)),
            ("schedule", Json::Str(sched.name().to_string())),
            ("measured_s", Json::Num(t)),
            ("idle_s", Json::Num(idle)),
            ("wall_s", Json::Num(wall)),
            ("inter_bytes", Json::Num(inter as f64)),
        ]);
        if sched == Schedule::ChunkedRescatter && ranks >= 4096 {
            assert!(
                wall < 60.0,
                "chunked_rescatter step at {ranks} ranks took {wall:.1}s wall \
                 (fleet-scale acceptance bar is 60s)"
            );
            println!("  [scale] chunked step at {ranks} ranks: {wall:.2}s wall (< 60s bar)");
        }
    }
    scale_table.print();

    // ---- health leg: sampled telemetry under an adversarial scenario ----
    // A node grid (ranks/8 nodes × 8) rather than flat: a link flap only
    // bites inter-node links, which a flat world does not have. The
    // scenario injects a 16x compute straggler on rank 0 plus a 4x
    // slowdown of node 1's inter links covering the whole run; the
    // detector sees only folded histograms and per-rank sums, and must
    // recover exactly {0} as the compute-flagged set.
    let topo = if ranks >= 16 && ranks % 8 == 0 {
        Topology::new(ranks / 8, 8)
    } else {
        Topology::flat(ranks)
    };
    let d = if smoke { 1usize << 14 } else { 1usize << 17 };
    let k = ((d as f64 * 0.001) as usize).max(1);
    let health_inputs: Vec<SparseTensor> = (0..ranks)
        .map(|r| {
            let a = ranks | 1;
            let mut support: Vec<u32> = (0..k).map(|i| ((i * a + r) % d) as u32).collect();
            support.sort_unstable();
            support.dedup();
            let values: Vec<f32> =
                (0..support.len()).map(|i| (i % 5) as f32 * 0.5 + 0.25).collect();
            SparseTensor::new(d, support, values)
        })
        .collect();
    let scenario = Scenario {
        stragglers: vec![(0, 16.0)],
        link_flaps: vec![LinkFlap { node: 1, start_s: 0.0, end_s: 1e6, factor: 4.0 }],
        seed: 7,
        ..Scenario::default()
    };
    // untraced twin step first: the overhead denominator for the
    // aggregation-cost row below
    let t0 = std::time::Instant::now();
    measured_fleet(Schedule::ChunkedRescatter, topo, slow, slow, &scenario, &health_inputs);
    let plain_wall = t0.elapsed().as_secs_f64();

    // step 0 retains only rank 0 (pre-marked exemplar); step 1 also
    // retains the ranks step 0 flagged — two steps exercise the
    // marking path without letting the exemplar trace grow unbounded
    let steps: u32 = if smoke { 1 } else { 2 };
    let tracer = Tracer::new(TraceLevel::Sampled, ranks);
    let cfg = SparseConfig { topology: Some(topo), ..SparseConfig::default() };
    let codec = SegmentCodec::raw(cfg.dense_switch);
    let mut fabric = FleetFabric::new(topo, slow, slow, scenario.clone());
    let base_compute = 2e-3;
    let mut exemplar_spans: Vec<Span> = Vec::new();
    let mut windows: Vec<StepWindow> = Vec::new();
    let t0 = std::time::Instant::now();
    for step in 0..steps {
        let bind = tracer.install(0);
        let virt0 = fabric.max_clock_s();
        for r in 0..ranks {
            let c0 = fabric.clock_s(r);
            fabric.elapse(r, base_compute * scenario.compute_factor(r, step as usize));
            tracer.record(vspan(SpanKind::Compute, r, step, c0, fabric.clock_s(r)));
        }
        let exch0: Vec<f64> = (0..ranks).map(|r| fabric.clock_s(r)).collect();
        fabric
            .allreduce(Schedule::ChunkedRescatter, &cfg, &codec, health_inputs.clone())
            .unwrap();
        let virt1 = fabric.max_clock_s();
        for r in 0..ranks {
            let e = fabric.clock_s(r);
            tracer.record(vspan(SpanKind::Exchange, r, step, exch0[r], e));
            tracer.record(vspan(SpanKind::Barrier, r, step, e, virt1));
            fabric.sync_to(r, virt1);
        }
        drop(bind); // flush the collector before draining this step
        tracer.end_health_step(step, virt1 - virt0, (virt0, virt1), Some(&scenario));
        windows.push(StepWindow {
            step,
            measured_s: virt1 - virt0,
            idle_mean_s: fabric.total_idle_s() / ranks as f64,
            virt0,
            virt1,
        });
        exemplar_spans.extend(tracer.drain(step));
    }
    let sampled_wall = t0.elapsed().as_secs_f64();

    let health = tracer.take_health().expect("sampled tracer carries fleet telemetry");
    let spans_folded = health.folded_spans();
    let meta = BTreeMap::from([
        ("schedule".to_string(), Json::Str("chunked_rescatter".to_string())),
        ("straggler".to_string(), Json::Str("0:16".to_string())),
        ("link_flap".to_string(), Json::Str("1:0-1000000:4".to_string())),
    ]);
    let report = health.report("vfabric_scaling_fleet", meta);
    assert_eq!(
        report.flagged_ranks,
        vec![0u32],
        "detector must recover exactly the injected straggler rank"
    );
    assert!(
        report.flags.iter().filter(|f| f.metric == "compute_s").all(|f| f.expected),
        "every compute flag must be scenario-confirmed"
    );
    let trace_ranks: BTreeSet<u32> = exemplar_spans.iter().map(|s| s.rank).collect();
    assert!(
        trace_ranks.len() <= report.max_exemplars + 2,
        "exemplar traces cover {} ranks at world {ranks} (budget {} + 2)",
        trace_ranks.len(),
        report.max_exemplars
    );
    print!("{}", report.summary());
    let trace = TraceReport {
        name: "vfabric_scaling_fleet".to_string(),
        level: TraceLevel::Sampled,
        ranks,
        meta: report.meta.clone(),
        steps: windows,
        spans: exemplar_spans,
        registry: tracer.registry().snapshot(),
    };
    match report.write() {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write health report: {e}"),
    }
    match trace.write() {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write exemplar trace: {e}"),
    }

    let per_step = sampled_wall / steps as f64;
    let overhead = (per_step - plain_wall) / plain_wall.max(1e-9);
    summary.row(&[
        ("leg", Json::Str("health".to_string())),
        ("ranks", Json::Num(ranks as f64)),
        ("steps", Json::Num(steps as f64)),
        ("plain_step_wall_s", Json::Num(plain_wall)),
        ("sampled_step_wall_s", Json::Num(per_step)),
        ("agg_overhead_frac", Json::Num(overhead)),
        ("spans_folded", Json::Num(spans_folded as f64)),
        ("exemplar_trace_ranks", Json::Num(trace_ranks.len() as f64)),
        ("flagged", Json::Str(format!("{:?}", report.flagged_ranks))),
    ]);
    // Fold-at-record keeps the per-span cost under the 200 ns contract
    // (asserted in codec_micro), but a chunked step is ~3n² message
    // events, each folding its Send/RecvWait/Recv spans — aggregate
    // overhead scales with event volume, not with a fixed wall
    // fraction. The row above records the measured ratio for the
    // trajectory; the assert is a regression backstop.
    assert!(
        per_step <= plain_wall * 2.5 + 5.0,
        "sampled aggregation overhead blew the backstop: \
         {per_step:.2}s per step vs {plain_wall:.2}s untraced"
    );
    println!(
        "  [health] {ranks} ranks x {steps} step(s): {spans_folded} spans folded, \
         {:+.0}% wall overhead, exemplar traces for {} rank(s), flagged {:?}",
        overhead * 100.0,
        trace_ranks.len(),
        report.flagged_ranks
    );

    match summary.write() {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write bench summary: {e}"),
    }
}

/// A virtual-clock-only span (wall times NaN), the shape the fleet
/// runner's synthesized step anatomy uses.
fn vspan(kind: SpanKind, rank: usize, step: u32, v0: f64, v1: f64) -> Span {
    Span {
        kind,
        lane: Lane::Cpu,
        rank: rank as u32,
        step,
        depth: 0,
        bytes: 0,
        label: None,
        wall0: f64::NAN,
        wall1: f64::NAN,
        virt0: v0,
        virt1: v1,
    }
}

/// One scenario of the sweep: a fabric configuration whose measured
/// schedule ranking is compared against `baseline_of` (None = this IS
/// a baseline).
struct Case {
    label: &'static str,
    topo: Topology,
    intra: Link,
    inter: Link,
    scenario: Scenario,
    baseline_of: Option<&'static str>,
}

fn main() {
    let argv: Vec<String> = std::env::args().collect();
    let smoke = argv.iter().any(|a| a == "--smoke");
    let value_of = |key: &str| -> Option<String> {
        argv.iter()
            .position(|a| a == key)
            .and_then(|i| argv.get(i + 1).cloned())
            .or_else(|| {
                argv.iter()
                    .find_map(|a| a.strip_prefix(&format!("{key}=")).map(String::from))
            })
    };
    let fleet = value_of("--fabric").as_deref() == Some("fleet");
    if fleet {
        let ranks: usize = value_of("--ranks")
            .map(|s| s.parse().expect("--ranks expects an integer"))
            .unwrap_or(4096);
        fleet_sweep(ranks, smoke);
        return;
    }
    let d = 1usize << 15;
    let n = 8usize;
    let flat = Topology::flat(n);
    let grid = Topology::new(2, 4);
    let slow = Link::mbps(100.0);
    let fast = Link::gbps(10.0);
    let strag = |f: f64| Scenario {
        stragglers: vec![(0, f)],
        seed: 7,
        ..Scenario::default()
    };
    let mut cases = vec![
        Case {
            label: "flat baseline",
            topo: flat,
            intra: slow,
            inter: slow,
            scenario: Scenario::none(7),
            baseline_of: None,
        },
        Case {
            label: "straggler 0:16",
            topo: flat,
            intra: slow,
            inter: slow,
            scenario: strag(16.0),
            baseline_of: Some("flat baseline"),
        },
        Case {
            label: "2x4 baseline",
            topo: grid,
            intra: fast,
            inter: slow,
            scenario: Scenario::none(7),
            baseline_of: None,
        },
        Case {
            label: "2x4 hetero node0:10mbps",
            topo: grid,
            intra: fast,
            inter: slow,
            scenario: Scenario {
                node_mbps: vec![(0, 10.0)],
                seed: 7,
                ..Scenario::default()
            },
            baseline_of: Some("2x4 baseline"),
        },
    ];
    if !smoke {
        cases.push(Case {
            label: "straggler 0:32",
            topo: flat,
            intra: slow,
            inter: slow,
            scenario: strag(32.0),
            baseline_of: Some("flat baseline"),
        });
        cases.push(Case {
            label: "link jitter 0.5",
            topo: flat,
            intra: slow,
            inter: slow,
            scenario: Scenario { link_jitter: 0.5, seed: 7, ..Scenario::default() },
            baseline_of: Some("flat baseline"),
        });
    }
    let densities: &[f64] = if smoke { &[0.001] } else { &[0.001, 0.01] };
    let w = SegWire::raw(0.5);
    let mut rng = Rng::new(42);
    let mut table = Table::new(
        "vfabric scaling — measured virtual step time per schedule × scenario",
        &["density", "scenario", "schedule", "measured", "idle(sum)", "formula@100Mbps"],
    );
    let mut summary = BenchSummary::new("vfabric_scaling");
    let mut inversions: Vec<String> = Vec::new();
    let mut cases_run = 0usize;
    for &density in densities {
        let k = ((d as f64 * density) as usize).max(1);
        let inputs: Vec<SparseTensor> = (0..n)
            .map(|_| {
                let support = sorted_support(&mut rng, d, k);
                let values: Vec<f32> = (0..k).map(|_| rng.next_gaussian() as f32).collect();
                SparseTensor::new(d, support, values)
            })
            .collect();
        // measured times per (case label, schedule)
        let mut times: Vec<(&str, Vec<(Schedule, f64)>)> = Vec::new();
        for case in &cases {
            let mut per_sched = Vec::new();
            for sched in Schedule::flat() {
                let (t, idle, bytes) =
                    measured(sched, case.topo, case.intra, case.inter, &case.scenario, &inputs);
                // what the closed form would claim, scenario-blind
                let formula = flat_schedule_time(sched, k as u64, d as u64, n, slow, w, true);
                table.row(&[
                    format!("{density:.3}"),
                    case.label.to_string(),
                    sched.name().to_string(),
                    format!("{:.3}ms", t * 1e3),
                    format!("{:.3}ms", idle * 1e3),
                    format!("{:.3}ms", formula * 1e3),
                ]);
                summary.row(&[
                    ("density", Json::Num(density)),
                    ("scenario", Json::Str(case.label.to_string())),
                    ("schedule", Json::Str(sched.name().to_string())),
                    ("measured_s", Json::Num(t)),
                    ("idle_s", Json::Num(idle)),
                    ("formula_s", Json::Num(formula)),
                    ("fabric_bytes", Json::Num(bytes as f64)),
                ]);
                per_sched.push((sched, t));
            }
            times.push((case.label, per_sched));
            cases_run += 1;
        }
        // ranking inversions: schedule pairs that swap order (2% margin)
        // between a scenario and its homogeneous baseline
        for case in &cases {
            let Some(base_label) = case.baseline_of else { continue };
            let base = &times.iter().find(|(l, _)| *l == base_label).unwrap().1;
            let cur = &times.iter().find(|(l, _)| *l == case.label).unwrap().1;
            for i in 0..base.len() {
                for j in i + 1..base.len() {
                    let (sa, ba) = base[i];
                    let (sb, bb) = base[j];
                    let (ca, cb) = (cur[i].1, cur[j].1);
                    let flipped = (ba < bb * 0.98 && ca > cb * 1.02)
                        || (bb < ba * 0.98 && cb > ca * 1.02);
                    if flipped {
                        let msg = format!(
                            "density {density}: {} vs {} swaps under {:?}",
                            sa.name(),
                            sb.name(),
                            case.label
                        );
                        println!("  [inversion] {msg}");
                        inversions.push(msg);
                    }
                }
            }
        }
        // acceptance: the balanced chunked schedule must beat the exact
        // ring under the straggler — validated against an independent
        // discrete-event mirror simulation before being pinned here
        if let Some((_, per)) = times.iter().find(|(l, _)| *l == "straggler 0:16") {
            let t_of = |s: Schedule| per.iter().find(|(x, _)| *x == s).unwrap().1;
            let chunked = t_of(Schedule::ChunkedRescatter);
            let ring = t_of(Schedule::RingRescatterExact);
            assert!(
                chunked < ring,
                "density {density}: chunked_rescatter {:.3}ms not faster than \
                 ring_rescatter_exact {:.3}ms under straggler 0:16",
                chunked * 1e3,
                ring * 1e3
            );
            println!(
                "  [straggler win] density {density}: chunked {:.3}ms vs ring_exact {:.3}ms",
                chunked * 1e3,
                ring * 1e3
            );
        }
    }
    table.print();
    // tracing acceptance: the traced decomposition of the straggler
    // step must explain ≥90% of the measured virtual time (it lands at
    // ~100% — the virtual clock cannot advance outside traced spans)
    let k = ((d as f64 * 0.001) as usize).max(1);
    let traced_inputs: Vec<SparseTensor> = (0..n)
        .map(|_| {
            let support = sorted_support(&mut rng, d, k);
            let values: Vec<f32> = (0..k).map(|_| rng.next_gaussian() as f32).collect();
            SparseTensor::new(d, support, values)
        })
        .collect();
    let (coverage, trace) = traced_coverage(flat, slow, &strag(16.0), &traced_inputs);
    print!("{}", trace.summary());
    match trace.write() {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write trace report: {e}"),
    }
    summary.set("trace_coverage", Json::Num(coverage));
    assert!(
        coverage >= 0.90,
        "traced critical path explains only {:.1}% of the measured straggler step",
        coverage * 100.0
    );
    summary.set("inversions", Json::Num(inversions.len() as f64));
    summary.set("cases", Json::Num(cases_run as f64));
    summary.set("smoke", Json::Bool(smoke));
    match summary.write() {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write bench summary: {e}"),
    }
    // acceptance: the measured ranking must invert somewhere the
    // formulas cannot see (they are identical across scenarios)
    assert!(
        !inversions.is_empty(),
        "no schedule-ranking inversion found across {cases_run} scenario runs"
    );
    println!(
        "{} ranking inversion(s) across {} scenario runs — conditions the closed forms miss",
        inversions.len(),
        cases_run
    );
}
