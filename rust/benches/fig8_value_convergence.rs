//! Fig 8: convergence of the curve-fitting value compressors (Fit-Poly
//! degree 5, Fit-DExp 4 coefficients) vs plain Top-r and the baseline.
//! Paper shape: both fits converge like Top-r, with Fit-DExp slightly
//! ahead of Fit-Poly and sending the least data.

use deepreduce::coordinator::ModelKind;
use deepreduce::util::benchkit::Table;
use deepreduce::xp;

fn main() {
    if !xp::need("mlp") {
        return;
    }
    let steps = 80;
    let workers = xp::FIG_WORKERS;
    let ratio = 0.01;

    let runs = vec![
        ("baseline".to_string(), xp::run(ModelKind::Mlp, "mlp", steps, workers, None).unwrap()),
        (
            "Top-1%".into(),
            xp::run(
                ModelKind::Mlp,
                "mlp",
                steps,
                workers,
                Some(xp::dr_value(ratio, "raw", f64::NAN)),
            )
            .unwrap(),
        ),
        (
            "DR[Fit-Poly]".into(),
            xp::run(ModelKind::Mlp, "mlp", steps, workers, Some(xp::dr_value(ratio, "fitpoly", 5.0)))
                .unwrap(),
        ),
        (
            "DR[Fit-DExp]".into(),
            xp::run(
                ModelKind::Mlp,
                "mlp",
                steps,
                workers,
                Some(xp::dr_value(ratio, "fitdexp", f64::NAN)),
            )
            .unwrap(),
        ),
        (
            "DR[QSGD-7b]".into(),
            xp::run(ModelKind::Mlp, "mlp", steps, workers, Some(xp::dr_value(ratio, "qsgd", 7.0)))
                .unwrap(),
        ),
    ];

    let headers: Vec<String> =
        std::iter::once("step".to_string()).chain(runs.iter().map(|(n, _)| n.clone())).collect();
    let headers_ref: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new("Fig 8 — loss timeline (value compressors)", &headers_ref);
    let stride = (steps / 12).max(1);
    for s in (0..steps).step_by(stride) {
        let mut row = vec![s.to_string()];
        for (_, r) in &runs {
            row.push(format!("{:.3}", r.steps[s].loss));
        }
        table.row(&row);
    }
    table.print();

    let mut summary = Table::new(
        "Fig 8 — endpoint summary",
        &["method", "final acc", "rel volume", "value-codec share of Top-1% volume"],
    );
    let topk_vol = runs[1].1.relative_volume();
    for (n, r) in &runs {
        summary.row(&[
            n.clone(),
            format!("{:.4}", r.final_aux(10)),
            xp::pct(r.relative_volume()),
            format!("{:.2}", r.relative_volume() / topk_vol),
        ]);
    }
    summary.print();
    println!("(paper: Fit-DExp ≈ 0.5x of Top-r volume, Fit-Poly ≈ 0.6x)");
}
