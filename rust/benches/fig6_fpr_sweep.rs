//! Fig 6 (a,b,c): effect of the Bloom-filter FPR on accuracy and data
//! volume for policies P0/P1/P2, with Top-r and Random-r sparsified
//! inputs (ResNet-20/CIFAR-10 stand-in, see DESIGN.md §4).
//!
//! Paper shape to reproduce:
//!   P0: accuracy flat in FPR; volume GROWS with FPR (extra positives)
//!   P1: volume shrinks with FPR; accuracy DROPS (random support)
//!   P2: volume shrinks with FPR; accuracy nearly flat

use deepreduce::coordinator::ModelKind;
use deepreduce::util::benchkit::Table;
use deepreduce::xp;

fn main() {
    if !xp::need("mlp") {
        return;
    }
    let steps = xp::FIG_STEPS;
    let workers = xp::FIG_WORKERS;
    let ratio = 0.01;
    let fprs = [0.0001, 0.001, 0.01, 0.1];

    // reference rows
    let base = xp::run(ModelKind::Mlp, "mlp", steps, workers, None).unwrap();
    eprintln!("baseline acc {:.4}", base.final_aux(10));

    for sparsifier in ["topk", "randomk"] {
        for policy in ["bloom_p0", "bloom_p1", "bloom_p2"] {
            let mut table = Table::new(
                &format!("Fig 6 — {policy} on {sparsifier}-1% ({steps} steps, {workers} workers)"),
                &["FPR", "final acc", "rel volume", "acc vs baseline"],
            );
            // the plain sparsifier row (FPR = n/a)
            let mut plain = xp::dr_index(ratio, "raw", f64::NAN);
            plain.sparsifier = sparsifier.into();
            let plain_r = xp::run(ModelKind::Mlp, "mlp", steps, workers, Some(plain)).unwrap();
            table.row(&[
                "none (raw idx)".into(),
                format!("{:.4}", plain_r.final_aux(10)),
                xp::pct(plain_r.relative_volume()),
                format!("{:+.4}", plain_r.final_aux(10) - base.final_aux(10)),
            ]);
            for &fpr in &fprs {
                let mut spec = xp::dr_index(ratio, policy, fpr);
                spec.sparsifier = sparsifier.into();
                let r = xp::run(ModelKind::Mlp, "mlp", steps, workers, Some(spec)).unwrap();
                table.row(&[
                    format!("{fpr}"),
                    format!("{:.4}", r.final_aux(10)),
                    xp::pct(r.relative_volume()),
                    format!("{:+.4}", r.final_aux(10) - base.final_aux(10)),
                ]);
            }
            table.print();
        }
    }
}
