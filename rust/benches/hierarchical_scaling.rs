//! Hierarchical scaling sweep: node grid (N×R) × gradient density ×
//! inter-node link speed, comparing the two-level leader schedule
//! against every flat schedule on the traffic class that dominates real
//! clusters — inter-node bytes. Fabric bytes are *measured* per link
//! class on the in-process transport (`Network::with_topology`); wall
//! time is *modelled* with the two-link-class α–β models from `simnet`
//! (validated against the wire in unit tests, DESIGN.md §8). Runs
//! without artifacts.
//!
//! Acceptance (asserted below): with a slow inter-node link, the
//! hierarchical schedule beats EVERY flat schedule on inter-node bytes
//! for at least two grid configurations.

use deepreduce::collective::{Network, Schedule, SparseConfig, Topology};
use deepreduce::simnet::{
    flat_schedule_time, hierarchical_bytes, hierarchical_time, Link, SegWire,
};
use deepreduce::tensor::SparseTensor;
use deepreduce::util::benchkit::{BenchSummary, Table};
use deepreduce::util::json::Json;
use deepreduce::util::prng::Rng;
use deepreduce::util::testkit::sorted_support;
use std::thread;

/// Run one schedule over a grid fabric; return (intra, inter) bytes.
fn measured_bytes(
    sched: Schedule,
    cfg: SparseConfig,
    topo: Topology,
    inputs: &[SparseTensor],
) -> (u64, u64) {
    let net = Network::with_topology(topo);
    let handles: Vec<_> = net
        .endpoints()
        .into_iter()
        .zip(inputs.to_vec())
        .map(|(ep, t)| thread::spawn(move || sched.build(cfg).allreduce(&ep, t).unwrap()))
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    (net.intra_bytes(), net.inter_bytes())
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let d = 1usize << 15;
    let w = SegWire::raw(0.5);
    let intra_link = Link::gbps(10.0);
    let slow = Link::mbps(100.0);
    let fast = Link::gbps(1.0);
    let mut rng = Rng::new(42);
    let mut table = Table::new(
        "hierarchical scaling — measured intra/inter fabric bytes, modelled two-class α–β time",
        &[
            "grid",
            "density",
            "schedule",
            "intra KB",
            "inter KB",
            "t@inter=100Mbps",
            "t@inter=1Gbps",
        ],
    );
    let mut summary = BenchSummary::new("hierarchical_scaling");
    let mut wins = 0usize;
    let mut cases = 0usize;
    let grids: &[(usize, usize)] = if smoke {
        &[(2, 4), (2, 8), (4, 4)]
    } else {
        &[(2, 4), (2, 8), (4, 4), (3, 3), (4, 2), (8, 2)]
    };
    for &(nodes, rpn) in grids {
        let topo = Topology::new(nodes, rpn);
        let n = topo.world();
        for density in [0.01f64, 0.05] {
            let k = ((d as f64 * density) as usize).max(1);
            let inputs: Vec<SparseTensor> = (0..n)
                .map(|_| {
                    let support = sorted_support(&mut rng, d, k);
                    let values: Vec<f32> =
                        (0..k).map(|_| rng.next_gaussian() as f32).collect();
                    SparseTensor::new(d, support, values)
                })
                .collect();
            let (ku, du) = (k as u64, d as u64);
            let mut worst_flat_inter = 0u64;
            let mut best_flat_inter = u64::MAX;
            for sched in Schedule::flat() {
                let cfg = SparseConfig { topology: Some(topo), ..SparseConfig::default() };
                let (intra, inter) = measured_bytes(sched, cfg, topo, &inputs);
                worst_flat_inter = worst_flat_inter.max(inter);
                best_flat_inter = best_flat_inter.min(inter);
                // flat schedules are topology-blind: bound their time by
                // the slow class carrying the whole exchange
                table.row(&[
                    topo.label(),
                    format!("{density:.2}"),
                    sched.name().to_string(),
                    format!("{:.1}", intra as f64 / 1e3),
                    format!("{:.1}", inter as f64 / 1e3),
                    format!("{:.5}s", flat_schedule_time(sched, ku, du, n, slow, w, true)),
                    format!("{:.5}s", flat_schedule_time(sched, ku, du, n, fast, w, true)),
                ]);
                summary.row(&[
                    ("grid", Json::Str(topo.label())),
                    ("density", Json::Num(density)),
                    ("schedule", Json::Str(sched.name().to_string())),
                    ("intra_bytes", Json::Num(intra as f64)),
                    ("inter_bytes", Json::Num(inter as f64)),
                    (
                        "t_inter_100mbps_s",
                        Json::Num(flat_schedule_time(sched, ku, du, n, slow, w, true)),
                    ),
                ]);
            }
            let cfg = SparseConfig {
                topology: Some(topo),
                inner: Schedule::GatherAll,
                ..SparseConfig::default()
            };
            let (h_intra, h_inter) = measured_bytes(Schedule::Hierarchical, cfg, topo, &inputs);
            table.row(&[
                topo.label(),
                format!("{density:.2}"),
                "hierarchical".to_string(),
                format!("{:.1}", h_intra as f64 / 1e3),
                format!("{:.1}", h_inter as f64 / 1e3),
                format!(
                    "{:.5}s",
                    hierarchical_time(ku, du, topo, intra_link, slow, w, Schedule::GatherAll, true)
                ),
                format!(
                    "{:.5}s",
                    hierarchical_time(ku, du, topo, intra_link, fast, w, Schedule::GatherAll, true)
                ),
            ]);
            summary.row(&[
                ("grid", Json::Str(topo.label())),
                ("density", Json::Num(density)),
                ("schedule", Json::Str("hierarchical".to_string())),
                ("intra_bytes", Json::Num(h_intra as f64)),
                ("inter_bytes", Json::Num(h_inter as f64)),
                (
                    "t_inter_100mbps_s",
                    Json::Num(hierarchical_time(
                        ku,
                        du,
                        topo,
                        intra_link,
                        slow,
                        w,
                        Schedule::GatherAll,
                        true,
                    )),
                ),
            ]);
            // model sanity at bench scale: the byte model assumes
            // disjoint supports, so on random (overlapping) supports it
            // is an upper bound — within 30% here; the strided worst
            // case is pinned at 2% in the simnet unit tests
            let (_, model_inter) =
                hierarchical_bytes(ku, du, topo, w, Schedule::GatherAll, true);
            let err = (model_inter as f64 - h_inter as f64) / model_inter as f64;
            assert!(
                (-0.02..0.30).contains(&err),
                "{}: inter model off by {err:.3} (model {model_inter}, wire {h_inter})",
                topo.label()
            );
            cases += 1;
            if h_inter < best_flat_inter {
                wins += 1;
                println!(
                    "  [win] {} density {density}: hierarchical {h_inter} B inter vs best flat \
                     {best_flat_inter} B (worst {worst_flat_inter} B)",
                    topo.label()
                );
            }
        }
    }
    table.print();
    summary.set("wins", Json::Num(wins as f64));
    summary.set("cases", Json::Num(cases as f64));
    summary.set("smoke", Json::Bool(smoke));
    match summary.write() {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write bench summary: {e}"),
    }
    // acceptance: the two-level schedule must beat EVERY flat schedule
    // on inter-node bytes for at least two grid configurations
    assert!(
        wins >= 2,
        "hierarchical beat every flat schedule on inter bytes in only {wins}/{cases} configs"
    );
    println!(
        "hierarchical beat every flat schedule on inter-node bytes in {wins}/{cases} configs"
    );
    println!("(leader-heavy grids (few nodes, many ranks/node) win biggest: only node sums");
    println!(" ever cross the slow boundary; flat ring stays closest thanks to its");
    println!(" block-contiguous placement — see DESIGN.md §8)");
}
