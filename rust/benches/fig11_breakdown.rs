//! Fig 11: per-iteration time breakdown (fwd/bwd compute, encode/decode,
//! communication) for NCF training at 100 Mbps / 1 Gbps / 10 Gbps links,
//! fp32 and fp16. Compute + codec are measured on this testbed;
//! communication time is modelled from exact wire bytes (DESIGN.md §4).
//! Paper shape: compression wins at low bandwidth, loses its edge as the
//! link gets faster.

use deepreduce::coordinator::{CompressionSpec, ModelKind};
use deepreduce::simnet::{allgather_time, allreduce_time, IterBreakdown, Link};
use deepreduce::util::benchkit::Table;
use deepreduce::xp;

fn main() {
    if !xp::need("ncf") {
        return;
    }
    let steps = 15;
    let workers = 4;

    // measured: dense baseline and two DR variants
    let base = xp::run(ModelKind::Ncf, "ncf", steps, workers, None).unwrap();
    let dr32 = xp::run(
        ModelKind::Ncf,
        "ncf",
        steps,
        workers,
        Some(CompressionSpec::identity("bloom_p0", 0.6, "qsgd", 7.0)),
    )
    .unwrap();
    let dr16 = xp::run(
        ModelKind::Ncf,
        "ncf",
        steps,
        workers,
        Some(CompressionSpec::identity("bloom_p0", 0.6, "fp16", f64::NAN)),
    )
    .unwrap();

    let per_step = |r: &deepreduce::coordinator::TrainReport| {
        (
            r.total_compute_s() / steps as f64 / workers as f64, // per worker
            (r.total_encode_s() + r.total_decode_s()) / steps as f64 / workers as f64,
            r.total_bytes_per_worker() / steps as u64,
        )
    };
    let (b_comp, _, b_bytes) = per_step(&base);
    let (d32_comp, d32_codec, d32_bytes) = per_step(&dr32);
    let (d16_comp, d16_codec, d16_bytes) = per_step(&dr16);

    let mut table = Table::new(
        "Fig 11 — NCF iteration time breakdown (modelled links)",
        &["link", "method", "compute s", "codec s", "comm s", "total s", "speedup"],
    );
    for (lname, link) in
        [("100Mbps", Link::mbps(100.0)), ("1Gbps", Link::gbps(1.0)), ("10Gbps", Link::gbps(10.0))]
    {
        let rows: Vec<(&str, IterBreakdown)> = vec![
            (
                "baseline fp32 (allreduce)",
                IterBreakdown {
                    compute_s: b_comp,
                    codec_s: 0.0,
                    comm_s: allreduce_time(b_bytes, workers, link),
                },
            ),
            (
                "baseline fp16 (allreduce)",
                IterBreakdown {
                    compute_s: b_comp,
                    codec_s: 0.0,
                    comm_s: allreduce_time(b_bytes / 2, workers, link),
                },
            ),
            (
                "DR[BF-P0|QSGD] fp32",
                IterBreakdown {
                    compute_s: d32_comp,
                    codec_s: d32_codec,
                    comm_s: allgather_time(d32_bytes, workers, link),
                },
            ),
            (
                "DR[BF-P0|fp16]",
                IterBreakdown {
                    compute_s: d16_comp,
                    codec_s: d16_codec,
                    comm_s: allgather_time(d16_bytes, workers, link),
                },
            ),
        ];
        let base_total = rows[0].1.total();
        for (name, b) in rows {
            table.row(&[
                lname.to_string(),
                name.to_string(),
                format!("{:.4}", b.compute_s),
                format!("{:.4}", b.codec_s),
                format!("{:.4}", b.comm_s),
                format!("{:.4}", b.total()),
                format!("{:.2}x", base_total / b.total()),
            ]);
        }
    }
    table.print();
    println!("(paper: gradient compression pays off only when comm/compute is");
    println!(" high — i.e. the 100Mbps rows — consistent with §6.4)");
}
