//! Fig 7: convergence timeline of the Bloom policies vs the baseline,
//! plain Top-r, and BF-naïve (FPR = 0.001). Paper shape: all policies
//! reach baseline accuracy; naïve suffers badly.

use deepreduce::coordinator::ModelKind;
use deepreduce::util::benchkit::Table;
use deepreduce::xp;

fn main() {
    if !xp::need("mlp") {
        return;
    }
    let steps = 80;
    let workers = xp::FIG_WORKERS;
    let ratio = 0.01;
    let fpr = 0.001;

    let mut runs = vec![(
        "baseline".to_string(),
        xp::run(ModelKind::Mlp, "mlp", steps, workers, None).unwrap(),
    )];
    runs.push((
        "Top-1%".into(),
        xp::run(ModelKind::Mlp, "mlp", steps, workers, Some(xp::dr_index(ratio, "raw", f64::NAN)))
            .unwrap(),
    ));
    for policy in ["bloom_naive", "bloom_p0", "bloom_p1", "bloom_p2"] {
        runs.push((
            policy.to_string(),
            xp::run(
                ModelKind::Mlp,
                "mlp",
                steps,
                workers,
                Some(xp::dr_index(ratio, policy, fpr)),
            )
            .unwrap(),
        ));
    }

    let headers: Vec<String> =
        std::iter::once("step".to_string()).chain(runs.iter().map(|(n, _)| n.clone())).collect();
    let headers_ref: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut table =
        Table::new(&format!("Fig 7 — accuracy timeline (FPR={fpr})"), &headers_ref);
    let stride = (steps / 12).max(1);
    for s in (0..steps).step_by(stride) {
        let mut row = vec![s.to_string()];
        for (_, r) in &runs {
            row.push(format!("{:.3}", r.steps[s].aux));
        }
        table.row(&row);
    }
    table.print();

    let mut summary = Table::new(
        "Fig 7 — endpoint summary",
        &["method", "final acc", "rel volume"],
    );
    for (n, r) in &runs {
        summary.row(&[
            n.clone(),
            format!("{:.4}", r.final_aux(10)),
            xp::pct(r.relative_volume()),
        ]);
    }
    summary.print();
    println!("(expected: bloom_naive well below the others; P2 volume < Top-1%)");
}
