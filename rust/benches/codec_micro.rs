//! Codec microbenchmarks: throughput of every index/value codec and the
//! substrate (bit I/O, hashing, top-r selection). This is the §Perf
//! profiling driver — not tied to one paper figure.

use deepreduce::compress::{index_by_name, value_by_name};
use deepreduce::obs;
use deepreduce::sparsify::top_r_indices;
use deepreduce::util::benchkit::Bench;
use deepreduce::util::bitio::BitWriter;
use deepreduce::util::hashkit::HashFamily;
use deepreduce::util::prng::Rng;
use deepreduce::util::testkit::{gradient_like, sorted_support};

fn main() {
    let mut bench = Bench::new();
    let mut rng = Rng::new(1);

    // ---- substrate ----
    let n = 1 << 20;
    bench.run_items("prng/xoshiro u64", n as u64, {
        let mut r = Rng::new(2);
        move || {
            let mut acc = 0u64;
            for _ in 0..n {
                acc = acc.wrapping_add(r.next_u64());
            }
            std::hint::black_box(acc);
        }
    });
    let hf = HashFamily::new(10, 1 << 20, 3);
    bench.run_items("hashkit/10-hash membership probe", n as u64, move || {
        let mut acc = 0u64;
        for i in 0..n as u64 {
            acc = acc.wrapping_add(hf.hash((i % 10) as usize, i));
        }
        std::hint::black_box(acc);
    });
    bench.run_items("bitio/write 8-bit chunks", n as u64, move || {
        let mut w = BitWriter::with_capacity(n);
        for i in 0..n as u64 {
            w.write_bits(i & 0xFF, 8);
        }
        std::hint::black_box(w.finish());
    });

    // ---- sparsification ----
    let d = 1 << 20;
    let g = gradient_like(&mut rng, d);
    bench.run_items("topr/quickselect 1% of 1M", d as u64, || {
        std::hint::black_box(top_r_indices(std::hint::black_box(&g), d / 100));
    });

    // ---- index codecs on a realistic support ----
    let dd = 262_144;
    let support = sorted_support(&mut rng, dd, dd / 100);
    for name in ["raw", "bitmap", "rle", "huffman", "delta_varint", "bloom_p0", "bloom_p2"] {
        let codec = index_by_name(name, 0.001, 5).unwrap();
        let enc = codec.encode(dd, &support);
        bench.run_items(&format!("index/{name} encode (r={})", support.len()), support.len() as u64, || {
            std::hint::black_box(codec.encode(dd, std::hint::black_box(&support)));
        });
        bench.run_items(&format!("index/{name} decode"), support.len() as u64, || {
            std::hint::black_box(codec.decode(dd, std::hint::black_box(&enc.bytes)).unwrap());
        });
    }

    // ---- value codecs ----
    let values = gradient_like(&mut rng, 65_536);
    let bytes = (values.len() * 4) as u64;
    for name in ["raw", "fp16", "deflate", "zstd", "qsgd", "fitpoly", "fitdexp", "sketch_huff"] {
        let codec = value_by_name(name, f64::NAN, 5).unwrap();
        let enc = codec.encode(&values);
        bench.run_bytes(&format!("value/{name} encode (64k f32)"), bytes, || {
            std::hint::black_box(codec.encode(std::hint::black_box(&values)));
        });
        bench.run_bytes(&format!("value/{name} decode"), bytes, || {
            std::hint::black_box(codec.decode(std::hint::black_box(&enc.bytes), values.len()).unwrap());
        });
    }
    // ---- observability hot path ----
    // the DESIGN.md §11 overhead contract: with tracing off (no tracer
    // installed on this thread), span()/count() must reduce to a
    // thread-local byte read plus a branch — no allocation, no clock
    // read. 100 ns/call is a generous ceiling; the real cost is ~1 ns.
    let iters = 1u64 << 20;
    let t0 = std::time::Instant::now();
    for i in 0..iters {
        let mut sp = obs::span(obs::SpanKind::Pack);
        sp.set_bytes(i);
        sp.label_with(|| unreachable!("dead span guards must not run label closures"));
        obs::count("bench.noop", 1);
        std::hint::black_box(&sp);
    }
    let per_call = t0.elapsed().as_secs_f64() / iters as f64;
    println!("obs/disabled span+count     {:>8.1} ns per call", per_call * 1e9);
    assert!(
        per_call < 100e-9,
        "disabled tracing costs {:.1} ns per span (contract: < 100 ns)",
        per_call * 1e9
    );

    // the DESIGN.md §14 streaming-aggregation contract: under `--trace
    // sampled` every span folds into the fleet telemetry instead of
    // being retained, so FleetTelemetry::fold (one log-bucket histogram
    // observe + per-rank running sums) must stay under 200 ns/span —
    // that bound, not a wall-clock fraction, is what keeps the sampled
    // plane viable at fleet message volumes.
    let world = 4096usize;
    let mut telemetry = obs::FleetTelemetry::new(world);
    let spans: Vec<obs::Span> = (0..(1usize << 16))
        .map(|i| obs::Span {
            kind: match i % 3 {
                0 => obs::SpanKind::Compute,
                1 => obs::SpanKind::RecvWait,
                _ => obs::SpanKind::Send,
            },
            lane: if i % 3 == 2 { obs::Lane::EgressInter } else { obs::Lane::Cpu },
            rank: (i % world) as u32,
            step: 0,
            depth: 0,
            bytes: 512,
            label: None,
            wall0: f64::NAN,
            wall1: f64::NAN,
            virt0: 0.0,
            virt1: 1e-4 + (i % 7) as f64 * 3e-5,
        })
        .collect();
    let reps = 16u64;
    let t0 = std::time::Instant::now();
    for _ in 0..reps {
        for s in &spans {
            std::hint::black_box(telemetry.fold(std::hint::black_box(s)));
        }
    }
    let per_fold = t0.elapsed().as_secs_f64() / (reps * spans.len() as u64) as f64;
    println!("obs/telemetry fold          {:>8.1} ns per span", per_fold * 1e9);
    assert!(
        per_fold < 200e-9,
        "sampled-telemetry fold costs {:.1} ns per span (contract: < 200 ns)",
        per_fold * 1e9
    );

    println!("\ncodec_micro done: {} measurements", bench.results().len());
}
