//! Fig 5: piecewise value fitting on a conv-layer-sized gradient —
//! fit quality and payload as the number of pieces grows (the paper
//! shows 8 pieces on ResNet-20's conv gradient).

use deepreduce::compress::value::FitPolyValue;
use deepreduce::compress::ValueCodec;
use deepreduce::util::benchkit::{Bench, Table};
use deepreduce::util::prng::Rng;
use deepreduce::util::stats::rel_l2_err;

fn main() {
    let d = 36_864; // the paper's conv gradient size
    let mut rng = Rng::new(5);
    let grad: Vec<f32> = (0..d)
        .map(|_| (rng.next_gaussian() as f32) * 10f32.powf(rng.next_f32() * 3.0 - 3.0))
        .collect();
    let mut sorted = grad.clone();
    sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());

    let mut table = Table::new(
        "Fig 5 — piecewise degree-5 fit of the sorted gradient (d=36864)",
        &["pieces", "payload B", "raw B", "rel L2 err", "encode"],
    );
    let mut bench = Bench::new();
    for pieces in [1usize, 2, 4, 8, 16, 32] {
        let codec = FitPolyValue::with_segments(5, pieces);
        let enc = codec.encode(&grad);
        let wire = codec.decode(&enc.bytes, d).unwrap();
        let err = rel_l2_err(&sorted, &wire);
        let m = bench.run(&format!("fitpoly/{pieces}p encode"), || {
            std::hint::black_box(codec.encode(std::hint::black_box(&grad)));
        });
        table.row(&[
            pieces.to_string(),
            enc.bytes.len().to_string(),
            (d * 4).to_string(),
            format!("{err:.5}"),
            deepreduce::util::benchkit::fmt_duration(m.median_s()),
        ]);
    }
    table.print();
    println!("(paper: 8 pieces reproduce the sorted curve almost exactly — Fig 5)");
}
