//! Fig 9: DeepReduce-on-Top-r vs stand-alone gradient compressors (3LC,
//! SketchML) — accuracy vs data volume on the large-model stand-in.
//! Paper shape: DR instantiations balance both axes; each stand-alone
//! method is biased toward one axis (3LC: accuracy at higher volume;
//! SketchML: volume at lower accuracy).

use deepreduce::coordinator::{CompressionSpec, ModelKind};
use deepreduce::util::benchkit::Table;
use deepreduce::xp;

fn main() {
    if !xp::need("mlp") {
        return;
    }
    let steps = 60;
    let workers = xp::FIG_WORKERS;
    let base = xp::run(ModelKind::Mlp, "mlp", steps, workers, None).unwrap();

    let mut rows: Vec<(String, f64, f32)> = vec![(
        "baseline (dense)".into(),
        base.relative_volume(),
        base.final_aux(10),
    )];
    // DR[BF-P2 | ∅] on Top-1%, FPR=0.001 (the paper's instantiation i)
    let r = xp::run(
        ModelKind::Mlp,
        "mlp",
        steps,
        workers,
        Some(xp::dr_index(0.01, "bloom_p2", 0.001)),
    )
    .unwrap();
    rows.push(("DR[BF-P2 | ∅]".into(), r.relative_volume(), r.final_aux(10)));
    // DR[∅ | Fit-Poly] (instantiation ii)
    let r = xp::run(ModelKind::Mlp, "mlp", steps, workers, Some(xp::dr_value(0.01, "fitpoly", 5.0)))
        .unwrap();
    rows.push(("DR[∅ | Fit-Poly]".into(), r.relative_volume(), r.final_aux(10)));
    // 3LC with sparsity multiplier 1 (dense path + EF)
    let r = xp::run_3lc(ModelKind::Mlp, "mlp", steps, workers, 1.0).unwrap();
    rows.push(("3LC (s=1)".into(), r.relative_volume(), r.final_aux(10)));
    // SketchML: quantile sketch values (2^6 buckets) + delta index on Top-1%
    let mut sk = CompressionSpec::topk(0.01, "delta_varint", f64::NAN, "sketch", 64.0);
    sk.seed = 11;
    let r = xp::run(ModelKind::Mlp, "mlp", steps, workers, Some(sk)).unwrap();
    rows.push(("SketchML (2^6 buckets)".into(), r.relative_volume(), r.final_aux(10)));

    let mut table = Table::new(
        &format!("Fig 9 — DeepReduce vs stand-alone compressors ({steps} steps)"),
        &["method", "rel volume", "final acc", "acc vs baseline"],
    );
    for (n, v, a) in &rows {
        table.row(&[
            n.clone(),
            xp::pct(*v),
            format!("{a:.4}"),
            format!("{:+.4}", a - rows[0].2),
        ]);
    }
    table.print();
    println!("(paper shape: DR points dominate the volume/accuracy trade-off corner)");
}
