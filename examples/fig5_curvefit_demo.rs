//! Fig 5 demo: sort a gradient's values and fit 8 piecewise polynomials
//! (the paper's illustration of why curve fitting compresses sorted
//! gradients so well). Prints an ASCII rendering plus fit statistics.
//!
//! Run (from `rust/`; no artifacts needed):
//! ```bash
//! cargo run --release --example fig5_curvefit_demo
//! ```

use deepreduce::compress::{value_by_name, ValueCodec};
use deepreduce::util::prng::Rng;
use deepreduce::util::stats::rel_l2_err;

fn main() -> anyhow::Result<()> {
    // synthetic conv-layer-like gradient (d = 36864, same as Fig 5/10)
    let d = 36_864;
    let mut rng = Rng::new(5);
    let grad: Vec<f32> = (0..d)
        .map(|_| (rng.next_gaussian() as f32) * 10f32.powf(rng.next_f32() * 3.0 - 3.0))
        .collect();

    let codec = value_by_name("fitpoly", 5.0, 1).unwrap();
    let enc = codec.encode(&grad);
    let wire = codec.decode(&enc.bytes, d)?; // values in sorted order
    // sorted truth for comparison
    let mut sorted = grad.clone();
    sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());

    // ASCII plot: 60 cols x 20 rows of sorted curve (.) vs fit (*)
    let (cols, rows) = (72usize, 20usize);
    // clip the plot to the 2nd..98th percentile: the heavy tails would
    // otherwise flatten the whole curve onto one row
    let y_min = sorted[d * 98 / 100];
    let y_max = sorted[d * 2 / 100];
    let mut canvas = vec![vec![b' '; cols]; rows];
    let to_row = |v: f32| -> usize {
        let t = ((v - y_min) / (y_max - y_min)).clamp(0.0, 1.0);
        ((1.0 - t) * (rows - 1) as f32).round() as usize
    };
    for c in 0..cols {
        let i = c * (d - 1) / (cols - 1);
        canvas[to_row(sorted[i])][c] = b'.';
    }
    for c in 0..cols {
        let i = c * (d - 1) / (cols - 1);
        let r = to_row(wire[i]);
        canvas[r][c] = if canvas[r][c] == b'.' { b'@' } else { b'*' };
    }
    println!("sorted gradient (.) vs 8-piece degree-5 fit (*) — '@' = overlap\n");
    for row in &canvas {
        println!("  |{}|", String::from_utf8_lossy(row));
    }

    let err = rel_l2_err(&sorted, &wire);
    let fit_bytes = enc.bytes.len();
    // paper §5.1 (we use ⌈log2 r⌉ = same here since r=d)
    let map_bits = (d as f64).log2().ceil() as usize;
    println!("\nfit payload: {fit_bytes} B for {d} values ({} B raw)", d * 4);
    println!("mapping: {} bits/value when combined with an index codec", map_bits);
    println!("relative L2 error of the fitted curve: {err:.4}");
    anyhow::ensure!(err < 0.2, "fit quality degraded");
    Ok(())
}
