//! CIFAR-scenario example (paper §6.1 setting): train the ResNet-20
//! stand-in with three gradient paths — no compression, plain Top-1%,
//! and Top-1% + BF-P2 — and compare convergence and data volume,
//! mirroring Fig 7 at small scale.
//!
//! Run (from `rust/`; needs `make artifacts` once):
//! ```bash
//! cargo run --release --example train_cifar_sim [steps]
//! ```

use deepreduce::coordinator::{CompressionSpec, ModelKind, TrainConfig, TrainReport, Trainer};
use deepreduce::util::benchkit::Table;

fn run(
    label: &str,
    steps: usize,
    compression: Option<CompressionSpec>,
) -> anyhow::Result<(String, TrainReport)> {
    let mut cfg = TrainConfig::new(ModelKind::Mlp, "mlp");
    cfg.workers = 4;
    cfg.steps = steps;
    cfg.compression = compression;
    cfg.log_every = steps / 5;
    eprintln!("--- {label} ---");
    let report = Trainer::new(cfg)?.run()?;
    Ok((label.to_string(), report))
}

fn main() -> anyhow::Result<()> {
    let steps: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(150);

    let mut runs = Vec::new();
    runs.push(run("baseline (dense fp32)", steps, None)?);
    let mut plain = CompressionSpec::topk(0.01, "raw", f64::NAN, "raw", f64::NAN);
    plain.seed = 1;
    runs.push(run("Top-1% (raw kv)", steps, Some(plain))?);
    let bf = CompressionSpec::topk(0.01, "bloom_p2", 0.001, "raw", f64::NAN);
    runs.push(run("DR[BF-P2] fpr=1e-3", steps, Some(bf))?);
    let bf_fit = CompressionSpec::topk(0.01, "bloom_p2", 0.001, "fitpoly", 5.0);
    runs.push(run("DR[BF-P2 | Fit-Poly]", steps, Some(bf_fit))?);

    let mut table = Table::new(
        &format!("CIFAR-sim convergence after {steps} steps (4 workers)"),
        &["method", "final loss", "final acc", "rel. volume", "codec s/step"],
    );
    for (label, r) in &runs {
        table.row(&[
            label.clone(),
            format!("{:.4}", r.final_loss()),
            format!("{:.4}", r.final_aux(10)),
            format!("{:.4}", r.relative_volume()),
            format!("{:.4}", (r.total_encode_s() + r.total_decode_s()) / steps as f64),
        ]);
    }
    table.print();

    // convergence timeline (Fig 7 shape): loss every steps/10
    let mut tl = Table::new(
        "timeline (train loss)",
        &["step", "baseline", "top-1%", "BF-P2", "BF-P2+Fit"],
    );
    let stride = (steps / 10).max(1);
    for s in (0..steps).step_by(stride) {
        tl.row(&[
            s.to_string(),
            format!("{:.3}", runs[0].1.steps[s].loss),
            format!("{:.3}", runs[1].1.steps[s].loss),
            format!("{:.3}", runs[2].1.steps[s].loss),
            format!("{:.3}", runs[3].1.steps[s].loss),
        ]);
    }
    tl.print();
    Ok(())
}
