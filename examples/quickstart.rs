//! Quickstart: compress one sparse gradient with several DeepReduce
//! instantiations and inspect volume + reconstruction error — the
//! paper's §3 framework walk-through (Fig 10a volume split) in one
//! program.
//!
//! Run (from `rust/`):
//! ```bash
//! cargo run --release --example quickstart
//! ```
//! No artifacts needed — this exercises the pure compression API.

use deepreduce::compress::{index_by_name, value_by_name, DeepReduce};
use deepreduce::sparsify::{Sparsifier, TopK};
use deepreduce::util::benchkit::Table;
use deepreduce::util::prng::Rng;
use deepreduce::util::stats::rel_l2_err;
use deepreduce::util::testkit::gradient_like;

fn main() -> anyhow::Result<()> {
    // a gradient the size of the paper's Fig 10 conv layer
    let d = 36_864;
    let mut rng = Rng::new(2021);
    let grad = gradient_like(&mut rng, d);

    // 1. sparsify: Top-1% (the paper's default)
    let mut topk = TopK::new(0.01);
    let sparse = topk.sparsify(&grad);
    println!(
        "gradient d={d}, top-1% keeps r={} values ({} B as raw <key,value>)\n",
        sparse.nnz(),
        sparse.kv_wire_bytes()
    );

    // 2. try a few instantiations DR_idx^val
    let mut table = Table::new(
        "DeepReduce quickstart",
        &["instantiation", "wire B", "vs <k,v>", "support", "value rel-err"],
    );
    for (idx, idx_param, val) in [
        ("raw", f64::NAN, "raw"),
        ("delta_varint", f64::NAN, "raw"),
        ("bloom_p0", 0.001, "raw"),
        ("bloom_p2", 0.001, "raw"),
        ("bloom_p2", 0.001, "fitpoly"),
        ("raw", f64::NAN, "qsgd"),
        ("raw", f64::NAN, "fitdexp"),
        // composed chains (`deepreduce list-codecs` for the full
        // registry): a second lossless stage over the head's bytes
        ("delta_varint+deflate", f64::NAN, "raw"),
        ("rle+deflate", f64::NAN, "raw"),
    ] {
        let dr = DeepReduce::new(
            index_by_name(idx, idx_param, 7).unwrap(),
            value_by_name(val, f64::NAN, 7).unwrap(),
        );
        // 3. encode -> container bytes (what goes on the wire)
        let container = dr.encode(&sparse, Some(&grad));
        let wire = container.to_bytes();

        // 4. decode on the "receiving worker"
        let received = deepreduce::compress::Container::from_bytes(&wire)?;
        let decoded = dr.decode(&received)?;

        // 5. measure
        let support_note = if decoded.indices() == sparse.indices() {
            "exact".to_string()
        } else {
            format!("{} ids", decoded.nnz())
        };
        let dense_in = sparse.to_dense();
        let dense_out = decoded.to_dense();
        let err = rel_l2_err(dense_in.data(), dense_out.data());
        table.row(&[
            dr.name(),
            wire.len().to_string(),
            format!("{:.3}", wire.len() as f64 / sparse.kv_wire_bytes() as f64),
            support_note,
            format!("{err:.4}"),
        ]);
    }
    table.print();
    println!("note: bloom_p0 reconstructs a superset of the support (the extra");
    println!("positions carry original gradient values), so dense-space 'error'");
    println!("includes useful signal the plain sparsifier dropped — see Fig 6a.");
    Ok(())
}
