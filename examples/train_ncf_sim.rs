//! NCF-scenario example (paper §6.3 "inherently sparse model", Table 2):
//! embedding gradients are sparse without any sparsifier, so DeepReduce
//! runs with the identity sparsifier. Compares DR[BF-P2|Fit-Poly],
//! DR[BF-P0|QSGD] and SKCompress-style DR[delta|sketch], plus baseline.
//!
//! Run (from `rust/`; needs `make artifacts` once):
//! ```bash
//! cargo run --release --example train_ncf_sim [steps]
//! ```

use deepreduce::coordinator::{CompressionSpec, ModelKind, TrainConfig, Trainer};
use deepreduce::util::benchkit::Table;

fn run(
    label: &str,
    steps: usize,
    compression: Option<CompressionSpec>,
) -> anyhow::Result<(String, deepreduce::coordinator::TrainReport)> {
    let mut cfg = TrainConfig::new(ModelKind::Ncf, "ncf");
    cfg.workers = 4;
    cfg.steps = steps;
    cfg.compression = compression;
    cfg.log_every = (steps / 4).max(1);
    eprintln!("--- {label} ---");
    let report = Trainer::new(cfg)?.run()?;
    Ok((label.to_string(), report))
}

fn main() -> anyhow::Result<()> {
    let steps: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(80);

    let mut runs = Vec::new();
    runs.push(run("baseline (dense fp32)", steps, None)?);
    runs.push(run(
        "DR[BF-P2 | Fit-Poly] fpr=0.01",
        steps,
        Some(CompressionSpec::identity("bloom_p2", 0.01, "fitpoly", 5.0)),
    )?);
    runs.push(run(
        "DR[BF-P0 | QSGD-7b] fpr=0.6",
        steps,
        Some(CompressionSpec::identity("bloom_p0", 0.6, "qsgd", 7.0)),
    )?);
    runs.push(run(
        "SKCompress-style DR[delta+huff | sketch]",
        steps,
        Some(CompressionSpec::identity("delta_huffman", f64::NAN, "sketch_huff", f64::NAN)),
    )?);

    let mut table = Table::new(
        &format!("NCF-sim (inherently sparse) after {steps} steps — Table 2 shape"),
        &["method", "rel. data volume", "hit rate", "codec s/step"],
    );
    for (label, r) in &runs {
        table.row(&[
            label.clone(),
            format!("{:.4}", r.relative_volume()),
            format!("{:.4}", r.final_aux(10)),
            format!("{:.4}", (r.total_encode_s() + r.total_decode_s()) / steps as f64),
        ]);
    }
    table.print();
    Ok(())
}
