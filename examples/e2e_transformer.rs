//! END-TO-END DRIVER (the DESIGN.md §6 mandated validation): train a
//! multi-million-parameter decoder-only transformer LM through all three
//! layers — L1 Pallas kernels → L2 JAX train-step → HLO artifact → L3
//! rust coordinator with DeepReduce (Top-r + BF-P2 + Fit-Poly) across 4
//! simulated workers — on a synthetic Markov corpus, logging the loss
//! curve (recorded in EXPERIMENTS.md).
//!
//! Run (from `rust/`; needs `make artifacts` once):
//! ```bash
//! cargo run --release --example e2e_transformer              # ~5M params, 150 steps
//! cargo run --release --example e2e_transformer -- --small   # 135k params, quick
//! cargo run --release --example e2e_transformer -- --full    # 27M params, 300 steps
//! cargo run --release --example e2e_transformer -- --steps 50
//! ```

use deepreduce::coordinator::{CompressionSpec, ModelKind, TrainConfig, Trainer};
use deepreduce::simnet::{allgather_time, allreduce_time, Link};
use deepreduce::util::benchkit::Table;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let small = args.iter().any(|a| a == "--small");
    let full = args.iter().any(|a| a == "--full");
    let steps = args
        .iter()
        .position(|a| a == "--steps")
        .and_then(|i| args.get(i + 1))
        .and_then(|s| s.parse().ok())
        .unwrap_or(if small { 60 } else if full { 300 } else { 150 });
    // default: the ~5M-parameter medium config (a few hundred steps fit
    // the single-core testbed); --full selects the 27M-parameter model
    let artifact = if small {
        "transformer_small"
    } else if full {
        "transformer_e2e"
    } else {
        "transformer_medium"
    };

    let mut cfg = TrainConfig::new(ModelKind::Transformer, artifact);
    cfg.workers = 4;
    cfg.steps = steps;
    cfg.log_every = (steps / 20).max(1);
    cfg.compression = Some(CompressionSpec::topk(0.01, "bloom_p2", 0.001, "fitpoly", 5.0));

    eprintln!("loading artifact '{artifact}' (this compiles the HLO once)...");
    let mut trainer = Trainer::new(cfg)?;
    let total = trainer.artifact().manifest.total_params();
    eprintln!(
        "model: {} parameters in {} tensors; 4 workers; DR[topk+bloom_p2|fitpoly]",
        total,
        trainer.artifact().manifest.params.len()
    );
    let t0 = std::time::Instant::now();
    let report = trainer.run()?;
    let wall = t0.elapsed().as_secs_f64();

    // --- loss curve (EXPERIMENTS.md §E2E) ---
    let mut curve = Table::new("e2e loss curve", &["step", "loss", "bytes/worker"]);
    let stride = (steps / 15).max(1);
    for s in (0..steps).step_by(stride) {
        let m = &report.steps[s];
        curve.row(&[s.to_string(), format!("{:.4}", m.loss), m.bytes_per_worker.to_string()]);
    }
    let last = report.steps.last().unwrap();
    curve.row(&[
        (steps - 1).to_string(),
        format!("{:.4}", last.loss),
        last.bytes_per_worker.to_string(),
    ]);
    curve.print();

    // --- summary + modelled comm benefit (Fig 11 style) ---
    let dense = (total * 4) as u64;
    let sparse_blob = report.steps.last().unwrap().bytes_per_worker;
    let mut summary = Table::new(
        "e2e summary",
        &["metric", "value"],
    );
    summary.row(&["initial loss".into(), format!("{:.4}", report.steps[0].loss)]);
    summary.row(&["final loss".into(), format!("{:.4}", report.final_loss())]);
    summary.row(&["relative data volume".into(), format!("{:.4}", report.relative_volume())]);
    summary.row(&["wall time (s)".into(), format!("{wall:.1}")]);
    summary.row(&[
        "compute s/step".into(),
        format!("{:.3}", report.total_compute_s() / steps as f64),
    ]);
    summary.row(&[
        "codec s/step".into(),
        format!("{:.3}", (report.total_encode_s() + report.total_decode_s()) / steps as f64),
    ]);
    let links =
        [("100Mbps", Link::mbps(100.0)), ("1Gbps", Link::gbps(1.0)), ("10Gbps", Link::gbps(10.0))];
    for (name, link) in links {
        let t_dense = allreduce_time(dense, 4, link);
        let t_dr = allgather_time(sparse_blob, 4, link);
        summary.row(&[
            format!("modelled comm/step @{name} (dense -> DR)"),
            format!("{:.3}s -> {:.3}s ({:.1}x)", t_dense, t_dr, t_dense / t_dr.max(1e-9)),
        ]);
    }
    summary.print();

    anyhow::ensure!(
        report.final_loss() < report.steps[0].loss * 0.97,
        "e2e training did not reduce loss"
    );
    println!("E2E OK: loss {:.4} -> {:.4}", report.steps[0].loss, report.final_loss());
    Ok(())
}
